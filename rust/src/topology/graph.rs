//! Directed device graph `({s, V}, E)` from §III-A2.
//!
//! Vertices are the `n` fog devices; the aggregation server is implicit
//! (every device can reach it for parameter aggregation — the paper excludes
//! that traffic from the cost model). Edges are directed offloading links
//! `(i, j)` with per-interval capacities and costs stored separately in
//! [`crate::costs::CostSchedule`].
//!
//! Adjacency lists are kept **sorted ascending** at all times. That is a
//! load-bearing invariant, not a nicety: the movement solvers break ties by
//! neighbor-iteration order (first strict minimum wins in
//! `MovementProblem::best_neighbor`), and the sparse solver path
//! ([`crate::movement::sparse`]) promises bit-identical plans to the dense
//! path by iterating the same sorted neighbor slices. Storage is O(V + E)
//! with no per-edge set: `has_edge` is a binary search on the out-row.

/// Directed graph over `n` devices with O(log deg) edge queries and
/// O(degree) sorted adjacency iteration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Graph {
    n: usize,
    out: Vec<Vec<usize>>,
    inn: Vec<Vec<usize>>,
    m: usize,
}

impl Graph {
    pub fn empty(n: usize) -> Self {
        Graph { n, out: vec![Vec::new(); n], inn: vec![Vec::new(); n], m: 0 }
    }

    pub fn from_edges(n: usize, edges: &[(usize, usize)]) -> Self {
        let mut g = Graph::empty(n);
        for &(i, j) in edges {
            g.add_edge(i, j);
        }
        g
    }

    /// Add directed edge i -> j (idempotent; self-loops rejected).
    /// Insertion keeps both adjacency rows sorted.
    pub fn add_edge(&mut self, i: usize, j: usize) {
        assert!(i < self.n && j < self.n, "edge ({i},{j}) out of range n={}", self.n);
        if i == j {
            return;
        }
        let Err(pos) = self.out[i].binary_search(&j) else {
            return; // already present
        };
        self.out[i].insert(pos, j);
        let ipos = self.inn[j].binary_search(&i).unwrap_err();
        self.inn[j].insert(ipos, i);
        self.m += 1;
    }

    /// Add both i -> j and j -> i.
    pub fn add_undirected(&mut self, i: usize, j: usize) {
        self.add_edge(i, j);
        self.add_edge(j, i);
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn num_edges(&self) -> usize {
        self.m
    }

    pub fn has_edge(&self, i: usize, j: usize) -> bool {
        i < self.n && self.out[i].binary_search(&j).is_ok()
    }

    /// Out-neighborhood of i: devices i can offload to (sorted ascending).
    pub fn out_neighbors(&self, i: usize) -> &[usize] {
        &self.out[i]
    }

    /// In-neighborhood `N_i = {j : (j, i) ∈ E}` (Theorem 3's notation),
    /// sorted ascending.
    pub fn in_neighbors(&self, i: usize) -> &[usize] {
        &self.inn[i]
    }

    pub fn out_degree(&self, i: usize) -> usize {
        self.out[i].len()
    }

    /// All edges in row-major sorted order: (0, j₀), (0, j₁), …, (1, ·), …
    /// — the same order the old BTreeSet-backed representation produced.
    pub fn edges(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        (0..self.n).flat_map(move |i| self.out[i].iter().map(move |&j| (i, j)))
    }

    /// Average out-degree over all devices.
    pub fn avg_degree(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.num_edges() as f64 / self.n as f64
        }
    }

    /// Whether the graph, viewed undirected and including the implicit
    /// server (which links every device), is connected. Since the server
    /// connects all devices, this is trivially true for n >= 1; the method
    /// instead reports whether the *device-to-device* graph is connected,
    /// which the experiments use to characterize topologies.
    ///
    /// O(V + E): one DFS over the adjacency rows, no matrix — this (with
    /// [`Graph::degree_histogram`]) is the documented sparse-scale
    /// diagnostics path, safe to call on million-device topologies from
    /// the sparse generators. Only graph *generation* has dense
    /// offenders, and those are guarded
    /// ([`crate::topology::generators::DENSE_GENERATOR_MAX_N`]).
    pub fn is_connected_undirected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(v) = stack.pop() {
            for &w in self.out[v].iter().chain(self.inn[v].iter()) {
                if !seen[w] {
                    seen[w] = true;
                    count += 1;
                    stack.push(w);
                }
            }
        }
        count == self.n
    }

    /// Restrict to the active subset: edges with both endpoints active.
    /// Vertex ids are preserved (inactive vertices become isolated).
    ///
    /// The hot path no longer calls this per interval — sessions use
    /// [`crate::topology::ActiveView`] masks instead — but it stays as the
    /// reference semantics (and test oracle) for what a mask must mean.
    pub fn restrict(&self, active: &[bool]) -> Graph {
        assert_eq!(active.len(), self.n);
        let mut g = Graph::empty(self.n);
        for (i, j) in self.edges() {
            if active[i] && active[j] {
                g.add_edge(i, j);
            }
        }
        g
    }

    /// Out-degree histogram: `hist[k]` = number of devices with k out-edges.
    ///
    /// O(V + E) like [`Graph::is_connected_undirected`] — part of the
    /// sparse-scale diagnostics path; fine at any population size.
    pub fn degree_histogram(&self) -> Vec<usize> {
        let maxd = (0..self.n).map(|i| self.out[i].len()).max().unwrap_or(0);
        let mut hist = vec![0usize; maxd + 1];
        for i in 0..self.n {
            hist[self.out[i].len()] += 1;
        }
        hist
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_edge_basics() {
        let mut g = Graph::empty(4);
        g.add_edge(0, 1);
        g.add_edge(0, 1); // idempotent
        g.add_edge(1, 0);
        g.add_edge(2, 2); // self-loop rejected
        assert_eq!(g.num_edges(), 2);
        assert!(g.has_edge(0, 1));
        assert!(!g.has_edge(1, 2));
        assert_eq!(g.out_neighbors(0), &[1]);
        assert_eq!(g.in_neighbors(0), &[1]);
    }

    #[test]
    fn adjacency_stays_sorted_regardless_of_insertion_order() {
        let mut g = Graph::empty(6);
        for &j in &[5, 1, 3, 2, 4] {
            g.add_edge(0, j);
        }
        for &i in &[4, 2, 5] {
            g.add_edge(i, 3);
        }
        assert_eq!(g.out_neighbors(0), &[1, 2, 3, 4, 5]);
        assert_eq!(g.in_neighbors(3), &[0, 2, 4, 5]);
        // edges() iterates in row-major sorted order
        let e: Vec<_> = g.edges().collect();
        let mut sorted = e.clone();
        sorted.sort_unstable();
        assert_eq!(e, sorted);
        assert_eq!(g.num_edges(), e.len());
    }

    #[test]
    fn connectivity() {
        let mut g = Graph::empty(4);
        g.add_undirected(0, 1);
        g.add_undirected(2, 3);
        assert!(!g.is_connected_undirected());
        g.add_edge(1, 2);
        assert!(g.is_connected_undirected());
    }

    #[test]
    fn restrict_drops_inactive_edges() {
        let mut g = Graph::empty(3);
        g.add_undirected(0, 1);
        g.add_undirected(1, 2);
        let r = g.restrict(&[true, false, true]);
        assert_eq!(r.num_edges(), 0);
        assert_eq!(r.n(), 3);
    }

    #[test]
    fn degree_histogram_counts() {
        let mut g = Graph::empty(3);
        g.add_edge(0, 1);
        g.add_edge(0, 2);
        g.add_edge(1, 2);
        assert_eq!(g.degree_histogram(), vec![1, 1, 1]); // deg0:1 (v2), deg1:1 (v1), deg2:1 (v0)
    }

    #[test]
    #[should_panic]
    fn out_of_range_edge_panics() {
        let mut g = Graph::empty(2);
        g.add_edge(0, 5);
    }
}
