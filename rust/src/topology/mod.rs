//! Fog network topology substrate: directed device graphs, the generators
//! for every topology family the paper evaluates (Table I / §V-D), and the
//! node churn process of §V-E.

pub mod active;
pub mod dynamics;
pub mod generators;
pub mod graph;

pub use active::ActiveView;
pub use dynamics::{ChurnDelta, ChurnProcess};
pub use graph::Graph;
