//! Topology generators for the fog scenarios of Table I and §V.
//!
//! * `fully_connected` — §V-B's default (`E = {(i,j): i≠j}`).
//! * `erdos_renyi` — §V-C2's random graph with `P[(i,j) ∈ E] = ρ`.
//! * `watts_strogatz` — §V-D's social-network model (small world, each node
//!   wired to `k` ring neighbors with rewiring probability `beta`).
//! * `hierarchical` — §V-D: the `n/3` lowest-processing-cost nodes act as
//!   heads, each connected to two of the remaining `2n/3` nodes at random.
//! * `scale_free` — Barabási–Albert preferential attachment; degree
//!   distribution `N(k) ∝ k^(1-γ)` as assumed by Theorem 5.
//! * `star` — the single-edge-server scenario of Theorem 4.

use crate::topology::graph::Graph;
use crate::util::rng::Rng;

/// Largest `n` the inherently dense generators ([`fully_connected`],
/// [`erdos_renyi`]) accept. Their edge sets are Θ(n²) — at the paper's
/// scales (n ≤ a few hundred) that is nothing, but at the sparse-scale
/// regime the movement engine targets (N = 10⁵–10⁶ devices,
/// `bench_engine`'s `scaling` sweep) a dense graph would be hundreds of
/// gigabytes before the first interval runs. The guard turns that
/// inevitable OOM into an immediate, explained error; use the sparse
/// generators ([`random_geometric`], [`watts_strogatz`], [`scale_free`])
/// for large populations — the O(V+E) diagnostics
/// (`Graph::is_connected_undirected`, `Graph::degree_histogram`) scale
/// with them.
pub const DENSE_GENERATOR_MAX_N: usize = 20_000;

fn assert_dense_scale(generator: &str, n: usize) {
    assert!(
        n <= DENSE_GENERATOR_MAX_N,
        "{generator}(n = {n}) would build a Θ(n²)-edge graph \
         (limit: DENSE_GENERATOR_MAX_N = {DENSE_GENERATOR_MAX_N}); use a sparse generator \
         (random_geometric, watts_strogatz, scale_free) for large fog populations"
    );
}

/// Every ordered pair is a link. Dense by definition — guarded by
/// [`DENSE_GENERATOR_MAX_N`].
pub fn fully_connected(n: usize) -> Graph {
    assert_dense_scale("fully_connected", n);
    let mut g = Graph::empty(n);
    for i in 0..n {
        for j in 0..n {
            if i != j {
                g.add_edge(i, j);
            }
        }
    }
    g
}

/// Undirected Erdős–Rényi: each unordered pair linked (both directions)
/// with probability `rho`. The pair loop is Θ(n²) regardless of `rho` —
/// guarded by [`DENSE_GENERATOR_MAX_N`].
pub fn erdos_renyi(n: usize, rho: f64, rng: &mut Rng) -> Graph {
    assert_dense_scale("erdos_renyi", n);
    let mut g = Graph::empty(n);
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.bool(rho) {
                g.add_undirected(i, j);
            }
        }
    }
    g
}

/// Watts–Strogatz small world: ring lattice with `k` nearest neighbors per
/// node (k rounded up to even), each edge rewired with probability `beta`.
pub fn watts_strogatz(n: usize, k: usize, beta: f64, rng: &mut Rng) -> Graph {
    let k = k.max(2).min(n.saturating_sub(1));
    let half = k / 2;
    let mut g = Graph::empty(n);
    if n < 2 {
        return g;
    }
    for i in 0..n {
        for d in 1..=half.max(1) {
            let j = (i + d) % n;
            if rng.bool(beta) {
                // rewire: random non-self, non-duplicate target
                let mut tries = 0;
                loop {
                    let t = rng.below(n);
                    if t != i && !g.has_edge(i, t) {
                        g.add_undirected(i, t);
                        break;
                    }
                    tries += 1;
                    if tries > 4 * n {
                        g.add_undirected(i, j);
                        break;
                    }
                }
            } else {
                g.add_undirected(i, j);
            }
        }
    }
    g
}

/// Hierarchical topology (§V-D): heads = the `n/3` devices with the lowest
/// processing costs; each head is wired (bidirectionally) to two random
/// non-head devices. `costs[i]` is each device's representative processing
/// cost (e.g. time-averaged `c_i(t)`).
pub fn hierarchical(n: usize, costs: &[f64], rng: &mut Rng) -> Graph {
    assert_eq!(costs.len(), n);
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| costs[a].partial_cmp(&costs[b]).unwrap());
    let n_heads = (n / 3).max(1);
    let heads = &order[..n_heads];
    let leaves = &order[n_heads..];
    let mut g = Graph::empty(n);
    if leaves.is_empty() {
        return g;
    }
    for &h in heads {
        // two distinct random leaves per head (or one if only one exists)
        let picks = rng.sample_indices(leaves.len(), 2.min(leaves.len()));
        for p in picks {
            g.add_undirected(h, leaves[p]);
        }
    }
    g
}

/// Barabási–Albert preferential attachment with `m` edges per new node.
/// Produces the scale-free degree law `N(k) ∝ k^{-γ}`, γ ≈ 3 (Theorem 5
/// writes the fraction of devices with k neighbors as `Γ k^{1-γ}`).
pub fn scale_free(n: usize, m: usize, rng: &mut Rng) -> Graph {
    let m = m.max(1);
    let mut g = Graph::empty(n);
    if n == 0 {
        return g;
    }
    let seed = (m + 1).min(n);
    // seed clique
    for i in 0..seed {
        for j in (i + 1)..seed {
            g.add_undirected(i, j);
        }
    }
    // repeated-endpoint list: preferential attachment by degree
    let mut endpoints: Vec<usize> = Vec::new();
    for (i, j) in g.edges().collect::<Vec<_>>() {
        endpoints.push(i);
        endpoints.push(j);
    }
    for v in seed..n {
        let mut targets = Vec::new();
        let mut guard = 0;
        while targets.len() < m.min(v) && guard < 100 * n {
            let t = if endpoints.is_empty() {
                rng.below(v)
            } else {
                *rng.choose(&endpoints)
            };
            if t != v && !targets.contains(&t) {
                targets.push(t);
            }
            guard += 1;
        }
        for t in targets {
            g.add_undirected(v, t);
            endpoints.push(v);
            endpoints.push(t);
        }
    }
    g
}

/// Random geometric graph on the unit square: devices at uniform positions,
/// bidirectionally linked when within `radius`. The standard model for
/// physical-proximity fog deployments — expected degree ≈ nπr², so choosing
/// `radius ∝ 1/√n` keeps the graph sparse (O(n) edges) as n grows, which is
/// exactly what the million-device scaling bench needs. Built with a
/// uniform grid of cell size `radius` (3×3 neighborhood scan), O(n + E)
/// expected time — no O(n²) pair loop.
pub fn random_geometric(n: usize, radius: f64, rng: &mut Rng) -> Graph {
    random_geometric_with_positions(n, radius, rng).0
}

/// [`random_geometric`], also returning the sampled positions (used by the
/// scaling bench to derive distance-based link costs).
pub fn random_geometric_with_positions(
    n: usize,
    radius: f64,
    rng: &mut Rng,
) -> (Graph, Vec<(f64, f64)>) {
    assert!(radius > 0.0, "radius must be positive");
    let pos: Vec<(f64, f64)> = (0..n).map(|_| (rng.f64(), rng.f64())).collect();
    let mut g = Graph::empty(n);
    // grid bucketing: cell side = radius, so any pair within `radius` lies
    // in the same or an adjacent cell
    let cells = ((1.0 / radius).floor() as usize).clamp(1, n.max(1));
    let cell_of = |x: f64| -> usize { ((x * cells as f64) as usize).min(cells - 1) };
    let mut grid: Vec<Vec<usize>> = vec![Vec::new(); cells * cells];
    for (i, &(x, y)) in pos.iter().enumerate() {
        grid[cell_of(y) * cells + cell_of(x)].push(i);
    }
    let r2 = radius * radius;
    for (i, &(x, y)) in pos.iter().enumerate() {
        let (cx, cy) = (cell_of(x), cell_of(y));
        for gy in cy.saturating_sub(1)..=(cy + 1).min(cells - 1) {
            for gx in cx.saturating_sub(1)..=(cx + 1).min(cells - 1) {
                for &j in &grid[gy * cells + gx] {
                    if j <= i {
                        continue; // handle each unordered pair once
                    }
                    let (dx, dy) = (pos[j].0 - x, pos[j].1 - y);
                    if dx * dx + dy * dy <= r2 {
                        g.add_undirected(i, j);
                    }
                }
            }
        }
    }
    (g, pos)
}

/// Star: devices 0..n-1 all bidirectionally linked to a hub (device n-1 by
/// convention is NOT the hub — pass `hub` explicitly). Used for the
/// Theorem-4 edge-server scenario where the hub is the server-class node.
pub fn star(n: usize, hub: usize, ) -> Graph {
    assert!(hub < n);
    let mut g = Graph::empty(n);
    for i in 0..n {
        if i != hub {
            g.add_undirected(i, hub);
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fully_connected_degree() {
        let g = fully_connected(6);
        assert_eq!(g.num_edges(), 30);
        for i in 0..6 {
            assert_eq!(g.out_degree(i), 5);
        }
    }

    #[test]
    fn erdos_renyi_extremes() {
        let mut rng = Rng::new(1);
        let empty = erdos_renyi(8, 0.0, &mut rng);
        assert_eq!(empty.num_edges(), 0);
        let full = erdos_renyi(8, 1.0, &mut rng);
        assert_eq!(full.num_edges(), 8 * 7);
    }

    #[test]
    fn erdos_renyi_density_matches_rho() {
        let mut rng = Rng::new(2);
        let n = 40;
        let g = erdos_renyi(n, 0.3, &mut rng);
        let density = g.num_edges() as f64 / (n * (n - 1)) as f64;
        assert!((density - 0.3).abs() < 0.06, "density={density}");
    }

    #[test]
    fn watts_strogatz_ring_degree() {
        let mut rng = Rng::new(3);
        let g = watts_strogatz(20, 4, 0.0, &mut rng);
        // beta=0: pure ring lattice, every node degree k
        for i in 0..20 {
            assert_eq!(g.out_degree(i), 4, "node {i}");
        }
        assert!(g.is_connected_undirected());
    }

    #[test]
    fn watts_strogatz_rewiring_preserves_edge_count_roughly() {
        let mut rng = Rng::new(4);
        let g0 = watts_strogatz(30, 6, 0.0, &mut rng);
        let g1 = watts_strogatz(30, 6, 0.5, &mut rng);
        // each undirected edge contributes 2
        assert_eq!(g0.num_edges(), 30 * 6);
        let diff = (g1.num_edges() as i64 - g0.num_edges() as i64).abs();
        assert!(diff <= 30, "diff={diff}");
    }

    #[test]
    fn hierarchical_heads_are_cheapest() {
        let mut rng = Rng::new(5);
        let n = 12;
        let costs: Vec<f64> = (0..n).map(|i| i as f64).collect(); // 0..3 are heads
        let g = hierarchical(n, &costs, &mut rng);
        // every edge must touch a head (bipartite head-leaf structure)
        for (i, j) in g.edges() {
            assert!(i < 4 || j < 4, "edge ({i},{j}) between leaves");
        }
        // heads have degree >= 1
        for h in 0..4 {
            assert!(g.out_degree(h) >= 1);
        }
    }

    #[test]
    fn scale_free_has_hubs() {
        let mut rng = Rng::new(6);
        let g = scale_free(100, 2, &mut rng);
        assert!(g.is_connected_undirected());
        let hist = g.degree_histogram();
        let max_deg = hist.len() - 1;
        // preferential attachment must create hubs well above m
        assert!(max_deg >= 8, "max degree {max_deg}");
        let mean_deg = g.avg_degree();
        assert!(mean_deg < 2.0 * 2.0 * 2.0, "mean {mean_deg}");
    }

    #[test]
    fn star_shape() {
        let g = star(5, 0);
        assert_eq!(g.out_degree(0), 4);
        for i in 1..5 {
            assert_eq!(g.out_degree(i), 1);
            assert!(g.has_edge(i, 0) && g.has_edge(0, i));
        }
    }

    #[test]
    fn random_geometric_matches_brute_force() {
        let mut rng = Rng::new(7);
        let (g, pos) = random_geometric_with_positions(60, 0.25, &mut rng);
        let mut brute = Graph::empty(60);
        for i in 0..60 {
            for j in (i + 1)..60 {
                let (dx, dy) = (pos[i].0 - pos[j].0, pos[i].1 - pos[j].1);
                if dx * dx + dy * dy <= 0.25 * 0.25 {
                    brute.add_undirected(i, j);
                }
            }
        }
        assert_eq!(g, brute);
        assert!(g.num_edges() > 0);
    }

    #[test]
    fn random_geometric_sparse_at_scale() {
        let mut rng = Rng::new(8);
        let n = 5000;
        // radius ~ sqrt(12/(pi*n)): expected degree ~ 12 independent of n
        let radius = (12.0 / (std::f64::consts::PI * n as f64)).sqrt();
        let g = random_geometric(n, radius, &mut rng);
        let mean = g.avg_degree();
        assert!(mean > 4.0 && mean < 24.0, "mean degree {mean}");
        // O(n) edges, nowhere near dense
        assert!(g.num_edges() < 20 * n);
    }

    #[test]
    fn dense_generators_accept_up_to_the_guard() {
        // just below/at the boundary shape-checks cheaply via n small;
        // the guard itself is a pure comparison, so exercise the bound
        // logic with small numbers plus the constant
        assert!(DENSE_GENERATOR_MAX_N >= 1_000); // paper scales must always pass
        let g = fully_connected(8);
        assert_eq!(g.num_edges(), 8 * 7);
    }

    #[test]
    #[should_panic(expected = "use a sparse generator")]
    fn fully_connected_rejects_sparse_scale_populations() {
        fully_connected(DENSE_GENERATOR_MAX_N + 1);
    }

    #[test]
    #[should_panic(expected = "use a sparse generator")]
    fn erdos_renyi_rejects_sparse_scale_populations() {
        erdos_renyi(DENSE_GENERATOR_MAX_N + 1, 0.01, &mut Rng::new(1));
    }

    #[test]
    fn generators_deterministic() {
        let a = erdos_renyi(15, 0.4, &mut Rng::new(9));
        let b = erdos_renyi(15, 0.4, &mut Rng::new(9));
        assert_eq!(a, b);
        let c = scale_free(30, 2, &mut Rng::new(9));
        let d = scale_free(30, 2, &mut Rng::new(9));
        assert_eq!(c, d);
    }
}
