//! Aggregation data-plane contract (DESIGN.md §Perf rule 14): the
//! chunk-parallel federated average and the copy-on-write epoch store
//! must be *pure execution strategies* — bit-invariant overlays on the
//! historical serial, clone-per-device engine.
//!
//! Four properties are pinned here:
//! * **Geometry exactness** — with exactly-representable inputs, every
//!   contributor-chunk size, thread count, and element-block size
//!   reproduces the serial `aggregate` bitwise (property test).
//! * **Thread invariance** — with arbitrary float inputs the result is a
//!   function of the chunk geometry only, never of the worker count, and
//!   the default geometry replays the serial entry point bitwise
//!   (property test).
//! * **COW identity** — a session whose `Arc` sharing edges are forcibly
//!   severed after every interval (deep-unshared global + replicas)
//!   produces bitwise-identical output to the normal pointer-bump run,
//!   across churn, movement backends, participation schedules, and
//!   forced `--solver-threads` counts.
//! * **No aliasing leaks** — mid-period, a trainee's `Arc::make_mut`
//!   copy never mutates the shared global allocation or any partner
//!   replica; untrained synced devices keep aliasing the epoch.
//!
//! Everything here is pure CPU (stub compute, no XLA artifacts).

use std::sync::Arc;

use fogml::config::{Churn, EngineConfig, Method, MovementBackend, SolverThreads};
use fogml::fed::aggregator::{
    aggregate, aggregate_chunked, CHUNK_CONTRIBUTORS, CHUNK_ELEMS,
};
use fogml::fed::session::{run_with, Compute, Params, Substrates};
use fogml::fed::{EngineOutput, ParticipationSchedule, Session};
use fogml::prop::{for_all, Gen};
use fogml::runtime::HostTensor;

/// Same arithmetic stub the session unit tests use: params carry a
/// seed marker and a sample counter, so the full churn/movement/COW
/// bookkeeping is exercised without XLA artifacts.
struct StubCompute;

impl Compute for StubCompute {
    fn init_params(&self, seed: u64) -> anyhow::Result<Params> {
        Ok(vec![HostTensor::new(vec![2], vec![(seed % 97) as f32, 0.0])])
    }

    fn train_interval(
        &self,
        params: &mut Params,
        samples: &[u32],
    ) -> anyhow::Result<Option<f32>> {
        if samples.is_empty() {
            return Ok(None);
        }
        params[0].data[1] += samples.len() as f32;
        Ok(Some(1.0 / (1.0 + params[0].data[1])))
    }

    fn evaluate(&self, params: &[HostTensor]) -> anyhow::Result<f64> {
        Ok((params[0].data[1] as f64 / 1e4).tanh())
    }
}

fn stub_cfg() -> EngineConfig {
    EngineConfig {
        method: Method::NetworkAware,
        n: 6,
        t_max: 24,
        tau: 4,
        n_train: 600,
        n_test: 120,
        ..Default::default()
    }
}

fn assert_identical(a: &EngineOutput, b: &EngineOutput, label: &str) {
    assert_eq!(a.accuracy, b.accuracy, "{label}: accuracy");
    assert_eq!(a.accuracy_curve, b.accuracy_curve, "{label}: curve");
    assert_eq!(a.per_device_loss, b.per_device_loss, "{label}: losses");
    assert_eq!(a.ledger, b.ledger, "{label}: ledger");
    assert_eq!(
        a.movement.per_interval, b.movement.per_interval,
        "{label}: movement"
    );
    assert_eq!(a.similarity, b.similarity, "{label}: similarity");
    assert_eq!(a.mean_active, b.mean_active, "{label}: mean_active");
    assert_eq!(a.total_collected, b.total_collected, "{label}: collected");
}

// ---------------------------------------------------------------------------
// Chunk/thread/element-block invariance of `aggregate_chunked`
// ---------------------------------------------------------------------------

/// With dyadic-exact inputs — a power-of-two count of weight-1
/// contributors (zero-weight decoys interleaved) over small-integer
/// parameter values — every floating-point association is exact, so
/// *every* chunk size, thread count, and element blocking must land on
/// the serial result bit-for-bit. This pins the skip-nonpositive and
/// normalization contracts across chunk boundaries, not just the
/// fixed-geometry determinism.
#[test]
fn every_geometry_is_bitwise_exact_on_dyadic_inputs() {
    for_all("aggregate_dyadic_geometry", 40, |g: &mut Gen| {
        let positives = 1usize << g.usize_in(0, 5);
        let layers = g.usize_in(1, 2);
        let elems = g.usize_in(1, 40);
        let mut owned: Vec<(Params, f64)> = Vec::new();
        for _ in 0..positives {
            let params: Params = (0..layers)
                .map(|_| {
                    HostTensor::new(
                        vec![elems],
                        (0..elems).map(|_| g.usize_in(0, 64) as f32 - 32.0).collect(),
                    )
                })
                .collect();
            owned.push((params, 1.0));
            // zero-weight decoys: skipped by the accumulator, neutral in
            // the normalizer, but they shift chunk boundaries around
            while g.bool(0.3) {
                let decoy: Params = (0..layers)
                    .map(|_| HostTensor::new(vec![elems], vec![7.0; elems]))
                    .collect();
                owned.push((decoy, 0.0));
            }
        }
        let refs: Vec<(&Params, f64)> = owned.iter().map(|(p, h)| (p, *h)).collect();
        let serial = aggregate(&refs).unwrap().unwrap();
        for chunk in [1usize, 2, 3, 5, CHUNK_CONTRIBUTORS] {
            for threads in [1usize, 2, 4, 7] {
                for elems_per_block in [1usize, 3, 7, CHUNK_ELEMS] {
                    let out = aggregate_chunked(&refs, threads, chunk, elems_per_block)
                        .unwrap()
                        .unwrap();
                    assert_eq!(
                        out, serial,
                        "chunk={chunk} threads={threads} elems={elems_per_block}"
                    );
                }
            }
        }
    });
}

/// With arbitrary float inputs the chunked result may associate sums
/// differently from the serial chain — but it must be a function of the
/// chunk geometry *only*: forced chunks {2, 3} are identical at threads
/// {2, 4, 7} vs 1, element blocking is bit-neutral at every size, the
/// default geometry replays the serial entry point bitwise, and every
/// geometry agrees with serial to float tolerance.
#[test]
fn threads_never_change_bits_on_arbitrary_inputs() {
    for_all("aggregate_thread_invariance", 40, |g: &mut Gen| {
        let n = g.usize_in(1, 24);
        let elems = g.usize_in(1, 33);
        let owned: Vec<(Params, f64)> = (0..n)
            .map(|_| {
                let params: Params = vec![HostTensor::new(
                    vec![elems],
                    (0..elems).map(|_| g.f64_in(-2.0, 2.0) as f32).collect(),
                )];
                let h = if g.bool(0.2) { 0.0 } else { g.f64_in(0.1, 50.0) };
                (params, h)
            })
            .collect();
        let refs: Vec<(&Params, f64)> = owned.iter().map(|(p, h)| (p, *h)).collect();
        let serial = aggregate(&refs).unwrap();
        for chunk in [2usize, 3, CHUNK_CONTRIBUTORS] {
            let base = aggregate_chunked(&refs, 1, chunk, CHUNK_ELEMS).unwrap();
            for threads in [2usize, 4, 7] {
                for elems_per_block in [1usize, 5, CHUNK_ELEMS] {
                    let out =
                        aggregate_chunked(&refs, threads, chunk, elems_per_block).unwrap();
                    assert_eq!(
                        out, base,
                        "chunk={chunk} threads={threads} elems={elems_per_block}"
                    );
                }
            }
            match (&serial, &base) {
                (None, None) => {}
                (Some(s), Some(b)) => {
                    // n ≤ 24 < 512: the default geometry is one chunk and
                    // must be the serial chain bit-for-bit
                    if chunk == CHUNK_CONTRIBUTORS {
                        assert_eq!(s, b, "single default chunk diverged from serial");
                    }
                    for (st, bt) in s.iter().zip(b) {
                        for (x, y) in st.data.iter().zip(&bt.data) {
                            assert!(
                                (x - y).abs() <= 1e-5 * (1.0 + x.abs()),
                                "chunk={chunk}: {x} vs {y}"
                            );
                        }
                    }
                }
                _ => panic!("chunk={chunk}: Some/None disagreement with serial"),
            }
        }
    });
}

// ---------------------------------------------------------------------------
// End-to-end COW identity (pure CPU)
// ---------------------------------------------------------------------------

/// Run the session manually, forcibly severing every `Arc` sharing edge
/// after each interval — the global and all replicas become uniquely
/// owned deep copies, exactly the storage the pre-rule-14 engine kept.
/// If any step observed sharing (instead of just exploiting it), its
/// output would diverge from the normal run.
fn run_deep_unshared(cfg: &EngineConfig, sub: &Substrates) -> EngineOutput {
    let mut s = Session::new(cfg, sub, StubCompute).expect("session");
    for t in 0..cfg.t_max {
        s.step_churn(t);
        s.step_collect(t);
        s.step_movement(t);
        s.step_train(t).expect("train");
        s.step_aggregate(t).expect("aggregate");
        s.state.global = Arc::new((*s.state.global).clone());
        for p in s.state.device_params.iter_mut() {
            *p = Arc::new((**p).clone());
        }
    }
    s.finish().expect("finish")
}

/// The COW store is invisible to every observable output: pointer-bump
/// runs and forcibly deep-cloned runs agree bitwise across churn,
/// movement backends, and participation schedules.
#[test]
fn cow_and_deep_clone_runs_are_bit_identical() {
    let configs = [
        stub_cfg(),
        stub_cfg().with(|c| c.churn = Some(Churn { p_exit: 0.1, p_entry: 0.1 })),
        stub_cfg().with(|c| {
            c.movement_backend = MovementBackend::Sparse;
            c.churn = Some(Churn { p_exit: 0.05, p_entry: 0.05 });
        }),
        stub_cfg().with(|c| {
            c.participation = ParticipationSchedule::UniformK { k: 3 };
            c.churn = Some(Churn { p_exit: 0.1, p_entry: 0.1 });
        }),
        stub_cfg().with(|c| {
            c.participation = ParticipationSchedule::ImportanceK { k: 3 };
        }),
    ];
    for (ci, cfg) in configs.iter().enumerate() {
        let sub = Substrates::derive(cfg);
        let normal = run_with(cfg, &sub, StubCompute).expect("normal run");
        let unshared = run_deep_unshared(cfg, &sub);
        assert_identical(&normal, &unshared, &format!("config #{ci}, COW vs deep-clone"));
    }
}

/// Forced `--solver-threads` counts feed `aggregate_chunked` directly
/// from `step_aggregate`; at paper scale (n ≤ 512 contributors — one
/// chunk) every count must reproduce the serial run bitwise.
#[test]
fn forced_solver_threads_leave_runs_bit_identical() {
    let base = stub_cfg().with(|c| c.churn = Some(Churn { p_exit: 0.1, p_entry: 0.1 }));
    let sub = Substrates::derive(&base);
    let reference = run_with(&base, &sub, StubCompute).expect("serial run");
    for k in [2usize, 4, 7] {
        let cfg = base.clone().with(|c| c.solver_threads = SolverThreads::Fixed(k));
        // same substrate seed ⇒ only the worker count differs
        let out = run_with(&cfg, &Substrates::derive(&cfg), StubCompute).expect("forced run");
        assert_identical(&reference, &out, &format!("solver-threads={k}"));
    }
}

// ---------------------------------------------------------------------------
// Aliasing discipline of the COW store (pure CPU)
// ---------------------------------------------------------------------------

/// Mid-period, the `Arc::make_mut` in the dispatch path must hand each
/// trainee a *private* copy: the shared global allocation keeps its bits,
/// untrained synced devices keep aliasing it, and no two trainees share
/// an allocation. At every period end the pointer-bump resync restores
/// full sharing.
#[test]
fn trainee_copies_never_leak_into_shared_replicas() {
    let cfg = stub_cfg().with(|c| c.t_max = 12);
    let sub = Substrates::derive(&cfg);
    let mut s = Session::new(&cfg, &sub, StubCompute).expect("session");

    // fresh session: one allocation, n aliases
    for p in &s.state.device_params {
        assert!(Arc::ptr_eq(p, &s.state.global), "initial replicas must alias");
    }

    let mut saw_multi_trainee_interval = false;
    for t in 0..cfg.t_max {
        s.step_churn(t);
        s.step_collect(t);
        s.step_movement(t);
        let global_before: Params = (*s.state.global).clone();
        let replicas_before: Vec<Params> =
            s.state.device_params.iter().map(|p| (**p).clone()).collect();
        s.step_train(t).expect("train");

        // training must never write through a sharing edge
        assert_eq!(
            *s.state.global, global_before,
            "t={t}: a trainee mutated the shared global allocation"
        );
        let trained: Vec<usize> =
            (0..cfg.n).filter(|&i| s.state.h[i] > 0.0).collect();
        for i in 0..cfg.n {
            let p = &s.state.device_params[i];
            if s.state.h[i] > 0.0 {
                assert!(
                    !Arc::ptr_eq(p, &s.state.global),
                    "t={t}: trainee {i} still aliases the epoch after training"
                );
            } else {
                assert_eq!(
                    **p, replicas_before[i],
                    "t={t}: untrained device {i}'s replica changed bits"
                );
            }
        }
        // no two trainees may share an allocation either
        for (a, &i) in trained.iter().enumerate() {
            for &j in &trained[a + 1..] {
                assert!(
                    !Arc::ptr_eq(&s.state.device_params[i], &s.state.device_params[j]),
                    "t={t}: trainees {i} and {j} share one allocation"
                );
            }
        }
        if trained.len() >= 2 {
            saw_multi_trainee_interval = true;
        }

        s.step_aggregate(t).expect("aggregate");
        if (t + 1) % cfg.tau == 0 {
            // period end: the resync re-shares the epoch with every
            // active device (no churn here, so that is all of them)
            for (i, p) in s.state.device_params.iter().enumerate() {
                assert!(
                    Arc::ptr_eq(p, &s.state.global),
                    "t={t}: device {i} not re-shared after resync"
                );
                assert_eq!(s.state.h[i], 0.0, "t={t}: h not reset at period end");
            }
        }
    }
    assert!(
        saw_multi_trainee_interval,
        "test never exercised an interval with ≥ 2 concurrent trainees"
    );
    s.finish().expect("finish");
}
