//! Participation-schedule contract (DESIGN.md §Perf rule 13): device
//! sampling must be an *unbiased, deterministic overlay* on the engine.
//!
//! Four properties are pinned here:
//! * **Full identity** — the default `Full` schedule, an explicit
//!   `Full`, and any degenerate `k >= n_active` schedule are
//!   bit-identical: no participation state is materialized and no RNG
//!   is consumed, so pre-sampling outputs are reproduced exactly.
//! * **Determinism** — sampled runs (`UniformK`/`ImportanceK`) depend
//!   only on the config: re-runs, re-derived substrates, and both
//!   movement backends agree bitwise; with a PJRT backend, serial and
//!   pooled (`--jobs 1` vs `--jobs 4`, shared services) runs do too.
//! * **Unbiasedness** — over many sampled periods the Horvitz–Thompson
//!   reweighting (`h_i / π_i`) recovers the full-participation
//!   aggregate in expectation, for uniform and importance sampling.
//! * **Gating** — at most `k` devices train per period when sampling
//!   is in force.
//!
//! The identity/determinism/unbiasedness tests are pure CPU (stub
//! compute); only the pool-invariance test needs `make artifacts` and
//! self-skips without an XLA backend.

use fogml::config::{Churn, EngineConfig, Method, MovementBackend};
use fogml::coordinator::SimPool;
use fogml::experiments::common::seed_sweep;
use fogml::fed::aggregator::aggregate;
use fogml::fed::session::{run_with, Compute, Params, Substrates};
use fogml::fed::{self, EngineOutput, ParticipationSchedule, ParticipationState};
use fogml::runtime::HostTensor;

/// Same arithmetic stub the session unit tests use: params carry a
/// seed marker and a sample counter, so churn/movement/aggregation
/// bookkeeping is exercised without XLA artifacts.
struct StubCompute;

impl Compute for StubCompute {
    fn init_params(&self, seed: u64) -> anyhow::Result<Params> {
        Ok(vec![HostTensor::new(vec![2], vec![(seed % 97) as f32, 0.0])])
    }

    fn train_interval(
        &self,
        params: &mut Params,
        samples: &[u32],
    ) -> anyhow::Result<Option<f32>> {
        if samples.is_empty() {
            return Ok(None);
        }
        params[0].data[1] += samples.len() as f32;
        Ok(Some(1.0 / (1.0 + params[0].data[1])))
    }

    fn evaluate(&self, params: &[HostTensor]) -> anyhow::Result<f64> {
        Ok((params[0].data[1] as f64 / 1e4).tanh())
    }
}

fn stub_cfg() -> EngineConfig {
    EngineConfig {
        method: Method::NetworkAware,
        n: 6,
        t_max: 24,
        tau: 4,
        n_train: 600,
        n_test: 120,
        ..Default::default()
    }
}

fn run_stub(cfg: &EngineConfig) -> EngineOutput {
    run_with(cfg, &Substrates::derive(cfg), StubCompute).unwrap()
}

fn assert_identical(a: &EngineOutput, b: &EngineOutput, label: &str) {
    assert_eq!(a.accuracy, b.accuracy, "{label}: accuracy");
    assert_eq!(a.accuracy_curve, b.accuracy_curve, "{label}: curve");
    assert_eq!(a.per_device_loss, b.per_device_loss, "{label}: losses");
    assert_eq!(a.ledger, b.ledger, "{label}: ledger");
    assert_eq!(
        a.movement.per_interval, b.movement.per_interval,
        "{label}: movement"
    );
    assert_eq!(a.similarity, b.similarity, "{label}: similarity");
    assert_eq!(a.mean_active, b.mean_active, "{label}: mean_active");
    assert_eq!(a.total_collected, b.total_collected, "{label}: collected");
}

// ---------------------------------------------------------------------------
// Full identity + degenerate degradation (pure CPU)
// ---------------------------------------------------------------------------

/// The default config must behave exactly as before this knob existed:
/// an explicit `Full` and every degenerate `k >= n` schedule reproduce
/// the default output bitwise — with and without churn, so periods
/// whose active set shrinks below `n` (where `k >= n >= n_active`
/// still holds) are covered too.
#[test]
fn full_default_and_degenerate_k_are_bit_identical() {
    let configs = [
        stub_cfg(),
        stub_cfg().with(|c| c.churn = Some(Churn { p_exit: 0.1, p_entry: 0.1 })),
        stub_cfg().with(|c| {
            c.movement_backend = MovementBackend::Sparse;
            c.churn = Some(Churn { p_exit: 0.05, p_entry: 0.05 });
        }),
    ];
    for (ci, base) in configs.iter().enumerate() {
        let reference = run_stub(base);
        let n = base.n;
        let schedules = [
            ParticipationSchedule::Full,
            ParticipationSchedule::UniformK { k: n },
            ParticipationSchedule::UniformK { k: n + 64 },
            ParticipationSchedule::ImportanceK { k: n },
            ParticipationSchedule::ImportanceK { k: n + 64 },
        ];
        for s in schedules {
            let out = run_stub(&base.clone().with(|c| c.participation = s));
            assert_identical(&reference, &out, &format!("config #{ci}, default vs {s:?}"));
        }
    }
}

/// Heavy churn keeps `n_active < n` for most periods; `k = n` still
/// exceeds every active count, so the sampler must declare each period
/// degenerate and stay bitwise on the `Full` path — consuming no RNG
/// that could shift later periods.
#[test]
fn k_at_least_n_active_degrades_to_full_under_heavy_churn() {
    let base = stub_cfg().with(|c| {
        c.t_max = 40;
        c.churn = Some(Churn { p_exit: 0.25, p_entry: 0.15 });
    });
    let reference = run_stub(&base);
    for s in [
        ParticipationSchedule::UniformK { k: base.n },
        ParticipationSchedule::ImportanceK { k: base.n },
    ] {
        let out = run_stub(&base.clone().with(|c| c.participation = s));
        assert_identical(&reference, &out, &format!("heavy churn, Full vs {s:?}"));
    }
}

// ---------------------------------------------------------------------------
// Determinism of sampled runs (pure CPU)
// ---------------------------------------------------------------------------

/// Sampled runs are a pure function of the config: re-runs, runs from
/// independently re-derived substrates, and runs under a different
/// seed all behave deterministically; and the movement backend stays a
/// pure execution-strategy knob (§Perf rule 11) with the capacity-zero
/// participation overlay applied.
#[test]
fn sampled_runs_are_deterministic_and_backend_invariant() {
    for s in [
        ParticipationSchedule::UniformK { k: 2 },
        ParticipationSchedule::ImportanceK { k: 2 },
    ] {
        let cfg = stub_cfg().with(|c| {
            c.participation = s;
            c.churn = Some(Churn { p_exit: 0.1, p_entry: 0.1 });
        });
        let a = run_stub(&cfg);
        let b = run_stub(&cfg);
        assert_identical(&a, &b, &format!("{s:?} re-run"));

        for backend in [MovementBackend::Dense, MovementBackend::Sparse] {
            let forced = run_stub(&cfg.clone().with(|c| c.movement_backend = backend));
            assert_identical(&a, &forced, &format!("{s:?} auto vs {backend:?}"));
        }

        // a different seed draws a different sample path (sanity that
        // the schedule is actually in force, not silently Full)
        let other = run_stub(&cfg.clone().seeded(cfg.seed ^ 0x9E37));
        assert!(
            a.per_device_loss != other.per_device_loss
                || a.movement.per_interval != other.movement.per_interval,
            "{s:?}: reseeded run is suspiciously identical"
        );
    }
}

/// With sampling in force and no churn, at most `k` devices may train
/// in any interval — unsampled devices are offload-only sources and
/// never reach the compute backend.
#[test]
fn at_most_k_devices_train_per_interval() {
    let k = 2;
    let cfg = stub_cfg().with(|c| {
        c.participation = ParticipationSchedule::UniformK { k };
    });
    let out = run_stub(&cfg);
    for (t, row) in out.per_device_loss.iter().enumerate() {
        let trained = row.iter().filter(|l| l.is_some()).count();
        assert!(
            trained <= k,
            "interval {t}: {trained} devices trained with UniformK k={k}"
        );
    }
    // and some training actually happened (the gate is not "nobody")
    let total: usize = out
        .per_device_loss
        .iter()
        .flat_map(|row| row.iter())
        .filter(|l| l.is_some())
        .count();
    assert!(total > 0, "sampling starved the engine entirely");
}

// ---------------------------------------------------------------------------
// Statistical unbiasedness of the Horvitz–Thompson reweighting (pure CPU)
// ---------------------------------------------------------------------------

/// Drive the sampler directly for many periods over a fixed population
/// and check that the reweighted sums recover the full-participation
/// quantities in expectation:
/// * the HT numerator `Σ_{i∈S} h_i x_i / π_i` ≈ `Σ_i h_i x_i`,
/// * the HT denominator `Σ_{i∈S} h_i / π_i` ≈ `Σ_i h_i`,
/// * the ratio aggregate through `aggregator::aggregate` (exactly what
///   `step_aggregate` computes) ≈ the full aggregate, within a looser
///   tolerance (ratio estimators are consistent, not exactly unbiased).
/// Everything is seeded, so the tolerances are deterministic.
fn assert_ht_unbiased(schedule: ParticipationSchedule, label: &str) {
    let n = 12;
    let k = 4;
    let periods = 400;
    // fixed population: positive weights and values, plus the scores an
    // ImportanceK schedule samples by (spread wide enough to matter)
    let h: Vec<f64> = (0..n).map(|i| 1.0 + 0.5 * ((i * 7 % 5) as f64)).collect();
    let x: Vec<f64> = (0..n).map(|i| 0.5 + 0.1 * ((i * 3 % 11) as f64)).collect();
    let scores: Vec<f64> = (0..n).map(|i| 1.0 + ((i * 5 % 7) as f64)).collect();
    let active = vec![true; n];

    let true_num: f64 = (0..n).map(|i| h[i] * x[i]).sum();
    let true_den: f64 = h.iter().sum();
    let true_aggregate = true_num / true_den;

    let mut state =
        ParticipationState::new(schedule, n, 0xFED5).expect("sampling schedule needs state");
    let (mut sum_num, mut sum_den, mut sum_ratio) = (0.0, 0.0, 0.0);
    for _ in 0..periods {
        state.resolve_period(&active, |i| scores[i]);
        assert!(!state.full_period, "{label}: k < n must not degenerate");
        assert_eq!(
            state.sampled.iter().filter(|&&s| s).count(),
            k,
            "{label}: sampler must draw exactly k devices"
        );

        let mut num = 0.0;
        let mut den = 0.0;
        let mut contributions: Vec<(Params, f64)> = Vec::new();
        for i in 0..n {
            if !state.sampled[i] {
                continue;
            }
            let w = h[i] * state.weight_scale[i];
            num += w * x[i];
            den += w;
            contributions.push((vec![HostTensor::new(vec![1], vec![x[i] as f32])], w));
        }
        sum_num += num;
        sum_den += den;

        let refs: Vec<(&Params, f64)> =
            contributions.iter().map(|(p, w)| (p, *w)).collect();
        let agg = aggregate(&refs).unwrap().expect("positive weights");
        sum_ratio += agg[0].data[0] as f64;
    }

    let mean_num = sum_num / periods as f64;
    let mean_den = sum_den / periods as f64;
    let mean_ratio = sum_ratio / periods as f64;
    assert!(
        (mean_num - true_num).abs() < 0.05 * true_num,
        "{label}: HT numerator biased: mean {mean_num} vs true {true_num}"
    );
    assert!(
        (mean_den - true_den).abs() < 0.05 * true_den,
        "{label}: HT denominator biased: mean {mean_den} vs true {true_den}"
    );
    assert!(
        (mean_ratio - true_aggregate).abs() < 0.1 * true_aggregate,
        "{label}: HT aggregate off: mean {mean_ratio} vs true {true_aggregate}"
    );
}

#[test]
fn uniform_reweighting_is_unbiased() {
    assert_ht_unbiased(ParticipationSchedule::UniformK { k: 4 }, "UniformK");
}

#[test]
fn importance_reweighting_is_unbiased() {
    assert_ht_unbiased(ParticipationSchedule::ImportanceK { k: 4 }, "ImportanceK");
}

// ---------------------------------------------------------------------------
// Pool invariance (requires `make artifacts`; skips without a backend)
// ---------------------------------------------------------------------------

/// Sampled runs must honor the determinism contract of
/// `tests/determinism.rs` unchanged: serial `fed::run`, `--jobs 1`,
/// `--jobs 4`, and the shared-service pool all produce bit-identical
/// outputs — the participation RNG is owned by the session, never by
/// the execution strategy.
#[test]
fn sampled_runs_are_pool_invariant() {
    let Some(rt) = fogml::runtime::test_runtime() else { return };
    for s in [
        ParticipationSchedule::UniformK { k: 3 },
        ParticipationSchedule::ImportanceK { k: 3 },
    ] {
        let cfg = EngineConfig {
            method: Method::NetworkAware,
            n: 6,
            t_max: 20,
            tau: 5,
            n_train: 1200,
            n_test: 300,
            participation: s,
            churn: Some(Churn { p_exit: 0.03, p_entry: 0.03 }),
            ..Default::default()
        };
        let cfgs = seed_sweep(&cfg, 2);

        let serial: Vec<EngineOutput> = cfgs
            .iter()
            .map(|c| fed::run(c, &rt).expect("serial sampled run"))
            .collect();
        let pooled1 = SimPool::new(1).run_many(&cfgs).expect("sampled jobs=1");
        let pooled4 = SimPool::new(4).run_many(&cfgs).expect("sampled jobs=4");
        let shared = SimPool::with_services(4, 1)
            .run_many(&cfgs)
            .expect("sampled shared-service");

        for (j, r) in serial.iter().enumerate() {
            assert_identical(r, &pooled1[j], &format!("{s:?} seed #{j}, serial vs jobs=1"));
            assert_identical(r, &pooled4[j], &format!("{s:?} seed #{j}, serial vs jobs=4"));
            assert_identical(
                r,
                &shared[j],
                &format!("{s:?} seed #{j}, serial vs shared-service"),
            );
        }

        // and the degenerate schedule stays Full through the pool too
        let full = cfgs[0].clone().with(|c| c.participation = ParticipationSchedule::Full);
        let degenerate =
            cfgs[0].clone().with(|c| c.participation = ParticipationSchedule::UniformK {
                k: c.n + 1,
            });
        let a = fed::run(&full, &rt).expect("full run");
        let b = fed::run(&degenerate, &rt).expect("degenerate run");
        assert_identical(&a, &b, "runtime-backed Full vs degenerate UniformK");
    }
}
