//! Eval subsystem equivalence: the batched stacked eval path and the eval
//! schedules must never change what a run *learns*, and must change what
//! it *reports* only in the documented ways (DESIGN.md §Perf rule 8).
//! Requires `make artifacts`.
//!
//! What "the same" means:
//! * Everything outside the curve — ledger, movement, per-device losses,
//!   final accuracy (always a full scalar pass) — is bit-identical across
//!   every (schedule, path) combination: evaluation is read-only and
//!   draws from no shared RNG stream.
//! * `EvalPath::Scalar` + `EvalSchedule::Full` reproduces the
//!   pre-subsystem `eval_curve` (one `Trainer::evaluate` per aggregation)
//!   bit-for-bit.
//! * Batched vs scalar curves agree within |Δaccuracy| ≤ 5e-3 (§Perf
//!   rule 7's accuracy tolerance: identical per-slot math, but XLA may
//!   reorder the vmapped lowering's reductions, and device/host argmax
//!   tie-breaking can differ on exactly-tied logits).

use fogml::config::{Churn, EngineConfig, Method};
use fogml::fed::eval::{EvalPath, EvalSchedule};
use fogml::fed::{self, EngineOutput, LocalCompute, Session, Substrates, Trainer};
use fogml::runtime::Runtime;

const ACC_TOL: f64 = 5e-3;

fn small() -> EngineConfig {
    EngineConfig {
        method: Method::NetworkAware,
        n: 8,
        t_max: 20,
        tau: 5,
        n_train: 1600,
        n_test: 400,
        eval_curve: true,
        // churn varies the trainee sets, so curve points see genuinely
        // different global models
        churn: Some(Churn { p_exit: 0.05, p_entry: 0.05 }),
        ..Default::default()
    }
}

fn run_cfg(rt: &Runtime, f: impl FnOnce(&mut EngineConfig)) -> EngineOutput {
    fed::run(&small().with(f), rt).expect("session run")
}

fn assert_learning_identical(a: &EngineOutput, b: &EngineOutput, label: &str) {
    assert_eq!(a.ledger, b.ledger, "{label}: ledger");
    assert_eq!(a.movement.per_interval, b.movement.per_interval, "{label}: movement");
    assert_eq!(a.per_device_loss, b.per_device_loss, "{label}: losses");
    assert_eq!(a.mean_active, b.mean_active, "{label}: mean_active");
    assert_eq!(a.similarity, b.similarity, "{label}: similarity");
    // the final evaluation is a full scalar pass on every configuration
    assert_eq!(a.accuracy, b.accuracy, "{label}: final accuracy");
}

/// The Full/Scalar planner path is today's `eval_curve`, bit for bit:
/// stepping the same session manually and calling the plain full-pass
/// `Compute::evaluate` at every aggregation must reproduce the curve
/// exactly.
#[test]
fn full_scalar_schedule_reproduces_legacy_eval_curve() {
    let Some(rt) = fogml::runtime::test_runtime() else { return };
    let cfg = small().with(|c| c.eval_path = EvalPath::Scalar);
    let through_planner = fed::run(&cfg, &rt).expect("planner run");

    // the legacy loop: no curve inside the session; evaluate by hand
    let legacy_cfg = small().with(|c| c.eval_curve = false);
    let sub = Substrates::derive(&legacy_cfg);
    let trainer = Trainer::new(&rt, legacy_cfg.model, legacy_cfg.lr).unwrap();
    let compute = LocalCompute {
        rt: &rt,
        trainer: &trainer,
        train: &sub.train,
        test: &sub.test,
    };
    let mut session = Session::new(&legacy_cfg, &sub, compute).unwrap();
    let mut legacy_curve = Vec::new();
    for t in 0..legacy_cfg.t_max {
        session.step_churn(t);
        session.step_collect(t);
        session.step_movement(t);
        session.step_train(t).unwrap();
        session.step_aggregate(t).unwrap();
        if (t + 1) % legacy_cfg.tau == 0 {
            let acc = trainer.evaluate(&session.state.global, &sub.test).unwrap();
            legacy_curve.push((t + 1, acc));
        }
    }
    let legacy = session.finish().unwrap();

    assert_learning_identical(&through_planner, &legacy, "planner vs legacy");
    assert_eq!(
        through_planner.accuracy_curve, legacy_curve,
        "Full/Scalar curve must be bit-identical to the legacy loop"
    );
}

/// Batched, auto and scalar eval paths: learning is bit-identical, the
/// curve agrees within the accuracy tolerance.
#[test]
fn eval_paths_agree_within_tolerance() {
    let Some(rt) = fogml::runtime::test_runtime() else { return };
    let scalar = run_cfg(&rt, |c| c.eval_path = EvalPath::Scalar);
    let batched = run_cfg(&rt, |c| c.eval_path = EvalPath::Batched);
    let auto = run_cfg(&rt, |c| c.eval_path = EvalPath::Auto);

    for (other, label) in [(&batched, "batched"), (&auto, "auto")] {
        assert_learning_identical(&scalar, other, label);
        assert_eq!(scalar.accuracy_curve.len(), other.accuracy_curve.len());
        for ((ta, aa), (tb, ab)) in
            scalar.accuracy_curve.iter().zip(&other.accuracy_curve)
        {
            assert_eq!(ta, tb, "{label}: curve t");
            assert!(
                (aa - ab).abs() <= ACC_TOL,
                "{label}: curve t={ta}: scalar {aa} vs {ab}"
            );
        }
    }
    // the default full test set spans many chunks, so Auto stacks: its
    // curve should be the batched one
    assert_eq!(auto.accuracy_curve, batched.accuracy_curve);
    assert!(!scalar.accuracy_curve.is_empty());
}

/// The subset schedule: learning bit-identical to Full, deterministic
/// across reruns, shard-sized evaluations that stay statistically close
/// to the full pass.
#[test]
fn subset_schedule_is_deterministic_and_tracks_full() {
    let Some(rt) = fogml::runtime::test_runtime() else { return };
    let full = run_cfg(&rt, |c| c.eval_schedule = EvalSchedule::Full);
    let sub_a = run_cfg(&rt, |c| {
        c.eval_schedule = EvalSchedule::Subset { shards: 4 };
    });
    let sub_b = run_cfg(&rt, |c| {
        c.eval_schedule = EvalSchedule::Subset { shards: 4 };
    });

    assert_learning_identical(&full, &sub_a, "full vs subset");
    assert_eq!(sub_a.accuracy_curve, sub_b.accuracy_curve, "subset rerun");
    assert_eq!(full.accuracy_curve.len(), sub_a.accuracy_curve.len());
    for ((ta, fa), (tb, sa)) in
        full.accuracy_curve.iter().zip(&sub_a.accuracy_curve)
    {
        assert_eq!(ta, tb);
        // a 100-sample shard of a 400-sample test set: binomial noise,
        // ~3σ ≈ 0.15 — matched noise, not matched value
        assert!(
            (fa - sa).abs() <= 0.2,
            "t={ta}: full {fa} vs subset {sa} drifted beyond shard noise"
        );
    }
}
