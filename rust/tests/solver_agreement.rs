//! Cross-solver agreement and repair-safety properties for the movement
//! optimization (pure CPU — no XLA artifacts needed).
//!
//! 1. On `LinearR` instances the objective is linear over a product of
//!    simplices, so the Theorem-3 greedy vertex solution is globally
//!    optimal; the PGD solver (warm-started from it, best-iterate tracked
//!    under the instance's own objective) must agree with its cost to
//!    within float tolerance.
//! 2. `repair::repair` may move mass around to satisfy capacities, but it
//!    must never make a plan *more* infeasible, and always ends feasible.
//! 3. The edge-indexed sparse pipeline (`movement::solve_sparse_with`,
//!    DESIGN.md §Perf rule 11) is a *bit-identical* mirror of the dense
//!    one: same greedy tie-breaks, same PGD iterates, same repair moves —
//!    `to_dense()` of its plan equals the dense plan with `==`, across
//!    topologies, churn masks, discard models, capacities, and warm
//!    starts.
//! 4. The row-parallel execution layer (DESIGN.md §Perf rule 12) is
//!    bit-invariant to `SolverWorkspace::solver_threads`: chunk geometry
//!    is a function of n only and reductions combine per-chunk partials
//!    in ascending order, so threads ∈ {2, 4, 7} must reproduce the
//!    serial plans with exact `==` — on both backends, every discard
//!    model, under churn, capacities, and forced multi-chunk layouts.

use fogml::costs::{CapacityMode, CostSchedule};
use fogml::movement::convex::{self, PgdOptions};
use fogml::movement::problem::DiscardModel;
use fogml::movement::{self, greedy, repair, MovementPlan, MovementProblem, SolverWorkspace};
use fogml::prop::for_all;
use fogml::topology::generators::{erdos_renyi, random_geometric};
use fogml::topology::Graph;
use fogml::util::rng::Rng;

struct Instance {
    graph: Graph,
    costs: CostSchedule,
    d: Vec<f64>,
    inbound: Vec<f64>,
    active: Vec<bool>,
}

impl Instance {
    fn problem(&self, model: DiscardModel) -> MovementProblem<'_> {
        MovementProblem {
            t: 0,
            graph: &self.graph,
            active: &self.active,
            d: &self.d,
            inbound_prev: &self.inbound,
            costs: &self.costs,
            discard_model: model,
        }
    }
}

fn random_instance(g: &mut fogml::prop::Gen, capacitated: bool) -> Instance {
    let n = g.usize_in(2, 7);
    let graph = erdos_renyi(n, g.f64_in(0.2, 1.0), g.rng());
    let mut costs = CostSchedule::zeros(n, 2);
    for t in 0..2 {
        for i in 0..n {
            costs.compute[t][i] = g.f64_in(0.0, 1.0);
            costs.error_weight[t][i] = g.f64_in(0.0, 1.0);
            for j in 0..n {
                if i != j {
                    costs.link[t][i * n + j] = g.f64_in(0.0, 1.0);
                }
            }
        }
    }
    if capacitated {
        let cap = g.f64_in(2.0, 12.0);
        costs.set_capacities(CapacityMode::Uniform(cap));
    }
    let d: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 25.0)).collect();
    let inbound = vec![0.0; n];
    let active = vec![true; n];
    Instance { graph, costs, d, inbound, active }
}

/// Total constraint violation of a plan: negativity, simplex deviation,
/// link/node/receiver capacity excess. Zero iff feasible.
fn infeasibility(p: &MovementProblem, plan: &MovementPlan) -> f64 {
    let n = plan.n;
    let mut v = 0.0;
    for i in 0..n {
        let mut row = plan.r[i];
        v += (-plan.r[i]).max(0.0);
        for j in 0..n {
            let sij = plan.s(i, j);
            v += (-sij).max(0.0);
            row += sij;
            if i != j && sij > 0.0 {
                if !(p.graph.has_edge(i, j) && p.active[i] && p.active[j]) {
                    v += sij; // mass on a missing/inactive link
                } else {
                    let cap = p.costs.cap_link_at(p.t, i, j);
                    if cap.is_finite() {
                        v += (sij * p.d[i] - cap).max(0.0);
                    }
                }
            }
        }
        if p.d[i] > 0.0 && p.active[i] {
            v += (row - 1.0).abs();
        }
        // sender node capacity: own kept data + inbound being processed now
        let cap = p.costs.cap_node_at(p.t, i);
        if cap.is_finite() {
            v += (plan.s(i, i) * p.d[i] + p.inbound_prev[i] - cap).max(0.0);
        }
    }
    // receiver capacities: data received now is processed at t+1
    for j in 0..n {
        let cap = p.costs.cap_node_at(p.t + 1, j);
        if cap.is_finite() {
            let inbound: f64 = (0..n)
                .filter(|&i| i != j && p.d[i] > 0.0)
                .map(|i| plan.s(i, j) * p.d[i])
                .sum();
            v += (inbound - cap).max(0.0);
        }
    }
    v
}

/// Greedy (closed-form optimum) and PGD must agree on LinearR cost.
#[test]
fn prop_greedy_and_pgd_agree_on_linear_instances() {
    for_all("solver_agreement_linear", 40, |g| {
        let inst = random_instance(g, false);
        let p = inst.problem(DiscardModel::LinearR);
        let greedy_plan = greedy::solve(&p);
        let pgd_plan = convex::solve(&p, PgdOptions { iterations: 200, step0: 0.0, tol: 0.0 });

        let go = greedy_plan.objective(&p);
        let po = pgd_plan.objective(&p);
        // best-iterate tracking starts at the greedy warm start: PGD can
        // never be worse…
        assert!(po <= go + 1e-9, "pgd {po} worse than greedy {go}");
        // …and greedy is the global optimum of the linear objective, so
        // PGD cannot be meaningfully better either.
        assert!(
            (go - po).abs() <= 1e-6 * go.abs().max(1.0),
            "solvers disagree on a linear instance: greedy {go} vs pgd {po}"
        );
    });
}

/// Repair must never increase infeasibility, and must end feasible.
#[test]
fn prop_repair_never_increases_infeasibility() {
    for_all("repair_monotone", 60, |g| {
        let inst = random_instance(g, true);
        let model = match g.usize_in(0, 2) {
            0 => DiscardModel::LinearR,
            1 => DiscardModel::LinearG,
            _ => DiscardModel::Sqrt,
        };
        let p = inst.problem(model);
        // solver output ignores capacities -> frequently infeasible here
        let mut plan = match model {
            DiscardModel::Sqrt => {
                convex::solve(&p, PgdOptions { iterations: 60, step0: 0.0, tol: 0.0 })
            }
            _ => greedy::solve(&p),
        };
        let before = infeasibility(&p, &plan);
        repair::repair(&p, &mut plan);
        let after = infeasibility(&p, &plan);
        assert!(
            after <= before + 1e-9,
            "repair increased infeasibility: {before} -> {after}"
        );
        assert!(after <= 1e-6, "repair left violations: {after}");
        plan.assert_feasible(&p, 1e-6);
    });
}

/// The sparse pipeline must be bit-identical to the dense one: random ER
/// topologies × random churn masks × idle devices × all three discard
/// models × with/without capacities, compared with exact `==` after
/// `to_dense()`.
#[test]
fn prop_sparse_pipeline_is_bit_identical_to_dense() {
    for_all("sparse_dense_identity", 80, |g| {
        let capacitated = g.bool(0.5);
        let mut inst = random_instance(g, capacitated);
        // random churn mask and some idle devices (d = 0): both paths must
        // make the exact same keep-everything decisions for those rows
        for a in inst.active.iter_mut() {
            *a = g.bool(0.75);
        }
        for x in inst.d.iter_mut() {
            if g.bool(0.2) {
                *x = 0.0;
            }
        }
        let model = match g.usize_in(0, 2) {
            0 => DiscardModel::LinearR,
            1 => DiscardModel::LinearG,
            _ => DiscardModel::Sqrt,
        };
        let p = inst.problem(model);

        let mut dense_ws = SolverWorkspace::new();
        movement::solve_with(&p, &mut dense_ws);
        let mut sparse_ws = SolverWorkspace::new();
        movement::solve_sparse_with(&p, &mut sparse_ws);

        assert_eq!(
            sparse_ws.sparse.to_dense(),
            dense_ws.plan,
            "sparse pipeline diverged from dense ({model:?}, capacitated={capacitated})"
        );
        sparse_ws.sparse.assert_feasible(&p, 1e-6);
    });
}

/// Solve an instance on both backends with the given worker count and
/// chunk layout, returning both plans densified for exact comparison.
fn solve_both(
    p: &MovementProblem,
    threads: usize,
    chunk_rows: usize,
) -> (MovementPlan, MovementPlan) {
    let mut dense_ws = SolverWorkspace::new();
    dense_ws.solver_threads = threads;
    dense_ws.chunk_rows = chunk_rows;
    movement::solve_with(p, &mut dense_ws);
    let mut sparse_ws = SolverWorkspace::new();
    sparse_ws.solver_threads = threads;
    sparse_ws.chunk_rows = chunk_rows;
    movement::solve_sparse_with(p, &mut sparse_ws);
    (dense_ws.plan, sparse_ws.sparse.to_dense())
}

/// Plans must be bit-invariant to the solver worker count (DESIGN.md
/// §Perf rule 12): random ER and random-geometric topologies × churn
/// masks × idle devices × all three discard models × with/without
/// capacities, with `chunk_rows` forced down to 2–3 so even n ≤ 7
/// instances reduce across several chunks. Compared with exact `==`
/// against the single-worker reference, on both plan backends.
#[test]
fn prop_solver_threads_are_bit_invariant() {
    for_all("solver_threads_invariance", 60, |g| {
        let capacitated = g.bool(0.5);
        let mut inst = random_instance(g, capacitated);
        let n = inst.d.len();
        // half the cases swap in a random-geometric topology — the fog
        // shape the scaling bench sweeps — at a radius that keeps a mix
        // of connected and isolated devices
        if g.bool(0.5) {
            inst.graph = random_geometric(n, g.f64_in(0.3, 0.9), g.rng());
        }
        for a in inst.active.iter_mut() {
            *a = g.bool(0.75);
        }
        for x in inst.d.iter_mut() {
            if g.bool(0.2) {
                *x = 0.0;
            }
        }
        let model = match g.usize_in(0, 2) {
            0 => DiscardModel::LinearR,
            1 => DiscardModel::LinearG,
            _ => DiscardModel::Sqrt,
        };
        let p = inst.problem(model);
        let chunk_rows = g.usize_in(2, 3);

        let (dense_ref, sparse_ref) = solve_both(&p, 1, chunk_rows);
        assert_eq!(
            sparse_ref, dense_ref,
            "sparse diverged from dense at threads=1 ({model:?})"
        );
        for threads in [2usize, 4, 7] {
            let (dense, sparse) = solve_both(&p, threads, chunk_rows);
            assert_eq!(
                dense, dense_ref,
                "dense plan changed under threads={threads} ({model:?}, \
                 chunk_rows={chunk_rows}, capacitated={capacitated})"
            );
            assert_eq!(
                sparse, dense_ref,
                "sparse plan changed under threads={threads} ({model:?}, \
                 chunk_rows={chunk_rows}, capacitated={capacitated})"
            );
        }
    });
}

/// The same invariance at a size where the *production* chunk layout is
/// still a single chunk but a forced multi-chunk layout gives every
/// worker several chunks of real work: one fixed n = 48 geometric
/// instance, Sqrt model (the PGD path — gradients, projections, fused
/// objective reductions), uniform capacities (the repair path), solved
/// at threads ∈ {1, 2, 4, 7} × chunk layouts {default, 4 rows}.
#[test]
fn solver_threads_invariance_at_multichunk_scale() {
    let n = 48;
    let mut rng = Rng::new(4242);
    let graph = random_geometric(n, 0.35, &mut rng);
    let mut costs = CostSchedule::zeros(n, 2);
    for t in 0..2 {
        for i in 0..n {
            costs.compute[t][i] = rng.uniform(0.05, 0.6);
            costs.error_weight[t][i] = rng.uniform(0.2, 0.9);
            for j in 0..n {
                if i != j {
                    costs.link[t][i * n + j] = rng.uniform(0.1, 2.0);
                }
            }
        }
    }
    costs.set_capacities(CapacityMode::Uniform(40.0));
    let d: Vec<f64> = (0..n).map(|_| (rng.f64() * 20.0).floor()).collect();
    let inbound = vec![0.0; n];
    let active: Vec<bool> = (0..n).map(|_| rng.bool(0.8)).collect();
    let inst = Instance { graph, costs, d, inbound, active };
    let p = inst.problem(DiscardModel::Sqrt);

    for chunk_rows in [SolverWorkspace::new().chunk_rows, 4] {
        let (dense_ref, sparse_ref) = solve_both(&p, 1, chunk_rows);
        assert_eq!(sparse_ref, dense_ref, "backends diverged at threads=1");
        for threads in [2usize, 4, 7] {
            let (dense, sparse) = solve_both(&p, threads, chunk_rows);
            assert_eq!(
                dense, dense_ref,
                "dense n=48 plan changed under threads={threads}, chunk_rows={chunk_rows}"
            );
            assert_eq!(
                sparse, dense_ref,
                "sparse n=48 plan changed under threads={threads}, chunk_rows={chunk_rows}"
            );
        }
    }
}

/// Warm starts must preserve the identity too: with `warm_start` on in
/// both workspaces, repeated solves reuse the previous plan as the PGD
/// starting point, and every round must still match bitwise (round k's
/// plans are equal by induction, so round k+1 starts from identical
/// iterates).
#[test]
fn prop_warm_started_pgd_matches_across_backends() {
    for_all("sparse_dense_warm_identity", 30, |g| {
        let mut inst = random_instance(g, false);
        for x in inst.d.iter_mut() {
            if g.bool(0.2) {
                *x = 0.0;
            }
        }
        let p = inst.problem(DiscardModel::Sqrt);
        let mut dense_ws = SolverWorkspace::new();
        dense_ws.warm_start = true;
        let mut sparse_ws = SolverWorkspace::new();
        sparse_ws.warm_start = true;
        for round in 0..3 {
            movement::solve_with(&p, &mut dense_ws);
            movement::solve_sparse_with(&p, &mut sparse_ws);
            assert_eq!(
                sparse_ws.sparse.to_dense(),
                dense_ws.plan,
                "warm-started backends diverged in round {round}"
            );
        }
    });
}
