//! End-to-end engine integration tests: artifacts → runtime → movement →
//! training → aggregation → evaluation. Requires `make artifacts`.

use fogml::config::{CapacityPolicy, Churn, EngineConfig, InfoMode, Method};
use fogml::fed;
use fogml::movement::DiscardModel;

/// Small-but-real configuration: quick enough for CI, large enough that
/// learning signal and cost structure are both visible.
fn small(method: Method) -> EngineConfig {
    EngineConfig {
        method,
        n: 6,
        t_max: 30,
        tau: 5,
        lr: 0.05,
        n_train: 2400,
        n_test: 600,
        ..Default::default()
    }
}

#[test]
fn network_aware_learns_and_saves_cost() {
    let Some(rt) = fogml::runtime::test_runtime() else { return };

    let fed_out = fed::run(&small(Method::Federated), &rt).unwrap();
    let na_out = fed::run(&small(Method::NetworkAware), &rt).unwrap();

    // both learn far above chance (10 classes)
    assert!(fed_out.accuracy > 0.5, "federated acc {}", fed_out.accuracy);
    assert!(na_out.accuracy > 0.5, "network-aware acc {}", na_out.accuracy);
    // network-aware stays within a few points of federated (Table II claim)
    assert!(
        na_out.accuracy > fed_out.accuracy - 0.10,
        "network-aware lost too much accuracy: {} vs {}",
        na_out.accuracy,
        fed_out.accuracy
    );

    // federated processes everything it collects, moves nothing
    assert_eq!(fed_out.movement.offloaded(), 0);
    assert_eq!(fed_out.movement.discarded(), 0);
    assert_eq!(fed_out.movement.processed(), fed_out.movement.collected());
    assert_eq!(fed_out.ledger.transfer, 0.0);
    assert_eq!(fed_out.ledger.discard, 0.0);

    // network-aware must actually use the network and cut total cost
    assert!(na_out.movement.offloaded() > 0, "no offloading happened");
    assert!(
        na_out.ledger.total() < fed_out.ledger.total(),
        "movement did not reduce cost: {} vs {}",
        na_out.ledger.total(),
        fed_out.ledger.total()
    );

    // conservation: processed + discarded = collected (every point ends
    // somewhere; offloaded points are processed later or pending at T)
    let m = &na_out.movement;
    let accounted = m.processed() + m.discarded();
    let in_flight = m.offloaded() as i64
        - (m.processed() as i64 - (m.collected() as i64 - m.offloaded() as i64 - m.discarded() as i64));
    assert!(
        accounted <= m.collected() && m.collected() - accounted <= 64,
        "conservation broken: processed {} + discarded {} vs collected {} (in flight {in_flight})",
        m.processed(),
        m.discarded(),
        m.collected()
    );
}

#[test]
fn centralized_is_accuracy_upper_bound_ish() {
    let Some(rt) = fogml::runtime::test_runtime() else { return };
    let central = fed::run(&small(Method::Centralized), &rt).unwrap();
    let na = fed::run(&small(Method::NetworkAware), &rt).unwrap();
    assert!(central.accuracy > 0.6, "centralized acc {}", central.accuracy);
    // centralized should not lose to network-aware by more than noise
    assert!(central.accuracy > na.accuracy - 0.05);
    // no network costs in centralized
    assert_eq!(central.ledger.total(), 0.0);
}

#[test]
fn non_iid_similarity_increases_with_offloading() {
    let Some(rt) = fogml::runtime::test_runtime() else { return };
    let cfg = small(Method::NetworkAware).with(|c| c.iid = false);
    let out = fed::run(&cfg, &rt).unwrap();
    let (before, after) = out.similarity;
    assert!(before < 0.9, "non-iid start should not be fully similar");
    assert!(
        after >= before - 0.02,
        "similarity should not fall: {before} -> {after}"
    );
}

#[test]
fn capacity_constraints_increase_discards() {
    let Some(rt) = fogml::runtime::test_runtime() else { return };
    let uncon = fed::run(&small(Method::NetworkAware), &rt).unwrap();
    let capped = fed::run(
        &small(Method::NetworkAware).with(|c| c.capacity = CapacityPolicy::MeanArrivals),
        &rt,
    )
    .unwrap();
    assert!(
        capped.movement.discarded() >= uncon.movement.discarded(),
        "caps should not reduce discards: {} vs {}",
        capped.movement.discarded(),
        uncon.movement.discarded()
    );
}

#[test]
fn imperfect_information_is_mild() {
    let Some(rt) = fogml::runtime::test_runtime() else { return };
    let perfect = fed::run(&small(Method::NetworkAware), &rt).unwrap();
    let imperfect = fed::run(
        &small(Method::NetworkAware).with(|c| c.info = InfoMode::Estimated(6)),
        &rt,
    )
    .unwrap();
    // B vs C in Table III: minor changes only
    let rel = (imperfect.ledger.total() - perfect.ledger.total()).abs()
        / perfect.ledger.total().max(1e-9);
    assert!(rel < 0.5, "estimation blew up cost: rel diff {rel}");
    assert!(imperfect.accuracy > 0.45);
}

#[test]
fn churn_reduces_active_nodes_and_data() {
    let Some(rt) = fogml::runtime::test_runtime() else { return };
    let static_out = fed::run(&small(Method::NetworkAware), &rt).unwrap();
    let dynamic_out = fed::run(
        &small(Method::NetworkAware)
            .with(|c| c.churn = Some(Churn { p_exit: 0.05, p_entry: 0.02 })),
        &rt,
    )
    .unwrap();
    assert!(dynamic_out.mean_active < static_out.mean_active);
    assert!(dynamic_out.total_collected < static_out.total_collected);
}

#[test]
fn discard_models_all_run_and_differ_sensibly() {
    let Some(rt) = fogml::runtime::test_runtime() else { return };
    let base = small(Method::NetworkAware);
    let linear_r = fed::run(&base.clone().with(|c| c.discard_model = DiscardModel::LinearR), &rt).unwrap();
    let linear_g = fed::run(&base.clone().with(|c| c.discard_model = DiscardModel::LinearG), &rt).unwrap();
    let sqrt = fed::run(&base.clone().with(|c| c.discard_model = DiscardModel::Sqrt), &rt).unwrap();
    // -f·G and f·D·r share the same decision structure up to the f-decay
    // between t and t+1 (§IV-A2); their realized discard volumes must stay
    // close (paper Table IV: Di 125 vs 136)
    let diff = (linear_g.movement.discarded() as i64 - linear_r.movement.discarded() as i64).abs();
    assert!(
        diff <= (linear_r.movement.collected() / 10) as i64,
        "-f·G and f·D·r diverged: {} vs {}",
        linear_g.movement.discarded(),
        linear_r.movement.discarded()
    );
    for (name, out) in [("linear_r", &linear_r), ("linear_g", &linear_g), ("sqrt", &sqrt)] {
        assert!(
            out.accuracy > 0.45,
            "{name}: acc={} processed={} discarded={} offloaded={} of {}",
            out.accuracy,
            out.movement.processed(),
            out.movement.discarded(),
            out.movement.offloaded(),
            out.movement.collected()
        );
    }
}

#[test]
fn deterministic_under_seed() {
    let Some(rt) = fogml::runtime::test_runtime() else { return };
    let a = fed::run(&small(Method::NetworkAware), &rt).unwrap();
    let b = fed::run(&small(Method::NetworkAware), &rt).unwrap();
    assert_eq!(a.accuracy, b.accuracy);
    assert_eq!(a.ledger, b.ledger);
    assert_eq!(a.movement.collected(), b.movement.collected());
}
