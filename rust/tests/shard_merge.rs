//! Cross-process sweep sharding: shard(N) + merge must reproduce an
//! unsharded serial run **byte-identically** (tables and curve CSVs), and
//! the merge must refuse incomplete or inconsistent shard sets loudly.
//!
//! The shard/serial equivalence tests drive real engines and therefore
//! require `make artifacts` (like `tests/determinism.rs`); the format and
//! validation tests are pure CPU.

use std::fs;
use std::path::{Path, PathBuf};

use fogml::config::{EngineConfig, Method};
use fogml::coordinator::shard::{self, RunRecord, ShardFile, ShardFormat, ShardSpec};
use fogml::experiments::{self, ExpOptions};
use fogml::fed::{EngineOutput, IntervalStats, Ledger, MovementTotals};
use fogml::util::json::Json;

fn tiny_base() -> EngineConfig {
    EngineConfig {
        method: Method::NetworkAware,
        n: 4,
        t_max: 10,
        tau: 5,
        n_train: 400,
        n_test: 100,
        ..Default::default()
    }
}

/// Fresh scratch directory per test case (removed up front so reruns
/// never see stale shard files).
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fogml_shard_{}_{name}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

fn opts(out: &Path, curve: bool) -> ExpOptions {
    ExpOptions {
        seeds: 2,
        out_dir: out.to_string_lossy().into_owned(),
        curve,
        base: Some(tiny_base()),
        ..Default::default()
    }
}

fn read(dir: &Path, name: &str) -> String {
    fs::read_to_string(dir.join(name))
        .unwrap_or_else(|e| panic!("missing {name} in {}: {e}", dir.display()))
}

/// Serial run, N shard runs in the given on-disk format, merge — then
/// byte-compare every artifact. Running this for both [`ShardFormat`]s
/// proves the DESIGN §Perf rule-9 contract: binary merge ≡ JSON merge
/// ≡ serial, byte-identically (both are compared against the same
/// serial artifacts). Skips (returns) without an XLA backend:
/// shard/merge drives real engines; the format and validation tests
/// below stay pure CPU.
fn assert_shard_merge_identical(
    which: &str,
    shards: usize,
    curve: bool,
    format: ShardFormat,
    files: &[&str],
) {
    if !fogml::runtime::backend_available() {
        return;
    }
    let root = scratch(&format!("{which}_{shards}_{}", format.extension()));

    let serial_dir = root.join("serial");
    experiments::dispatch(which, &opts(&serial_dir, curve)).expect("serial run");

    let shard_dir = root.join("shards");
    for i in 1..=shards {
        let mut o = opts(&shard_dir, curve);
        o.shard = Some(ShardSpec { index: i, count: shards });
        o.shard_format = format;
        experiments::dispatch(which, &o).expect("shard run");
        let spec = ShardSpec { index: i, count: shards };
        assert!(
            shard_dir.join(spec.file_name(format)).exists(),
            "shard {i}/{shards} {format} file missing"
        );
    }
    // shard mode suppresses artifacts — only shard files appear
    for f in files {
        assert!(!shard_dir.join(f).exists(), "shard mode must not write {f}");
    }

    let merged_dir = root.join("merged");
    experiments::merge_with_opts(shard_dir.to_str().unwrap(), &opts(&merged_dir, curve))
        .expect("merge");

    for f in files {
        assert_eq!(
            read(&serial_dir, f),
            read(&merged_dir, f),
            "{which} sharded {shards} ways: {f} not byte-identical to serial"
        );
    }
}

#[test]
fn table3_shard2_and_shard3_merge_equal_serial() {
    assert_shard_merge_identical("table3", 2, false, ShardFormat::Json, &["table3.csv"]);
    assert_shard_merge_identical("table3", 3, false, ShardFormat::Json, &["table3.csv"]);
}

#[test]
fn table3_binary_shards_merge_equal_serial() {
    // same grid through .fsb shards: merged artifacts must be
    // byte-identical to serial, hence to the JSON-shard merge above
    assert_shard_merge_identical("table3", 2, false, ShardFormat::Binary, &["table3.csv"]);
    assert_shard_merge_identical("table3", 3, false, ShardFormat::Binary, &["table3.csv"]);
}

#[test]
fn fig9_curves_shard3_merge_equal_serial() {
    // fig9 emits both a table and a curve CSV (--curve), so this covers
    // the curve-reassembly path end to end
    let files = &["fig9_pexit.csv", "fig9_pexit_curve.csv"];
    assert_shard_merge_identical("fig9", 3, true, ShardFormat::Json, files);
    assert_shard_merge_identical("fig9", 3, true, ShardFormat::Binary, files);
}

// ---------------------------------------------------------------------------
// Format round-trip + validation (pure CPU)
// ---------------------------------------------------------------------------

fn awkward_output() -> EngineOutput {
    let mut movement = MovementTotals::default();
    movement.push(IntervalStats { collected: 10, processed: 7, offloaded: 2, discarded: 1 });
    movement.push(IntervalStats { collected: 0, processed: 3, offloaded: 0, discarded: 0 });
    EngineOutput {
        accuracy: 0.1 + 0.2, // 0.30000000000000004 — shortest-roundtrip torture
        accuracy_curve: vec![(5, 1.0 / 3.0), (10, 0.999_999_999_999_999_9)],
        per_device_loss: vec![
            vec![Some(0.333_333_34_f32), None],
            vec![None, Some(f32::NAN)],
        ],
        ledger: Ledger { process: 1e-17, transfer: 123_456_789.25, discard: 0.0 },
        movement,
        similarity: (0.25, f64::INFINITY),
        mean_active: 3.7,
        total_collected: 987_654_321,
    }
}

fn assert_output_eq(a: &EngineOutput, b: &EngineOutput) {
    assert_eq!(a.accuracy, b.accuracy, "accuracy");
    assert_eq!(a.accuracy_curve, b.accuracy_curve, "curve");
    assert_eq!(a.per_device_loss.len(), b.per_device_loss.len(), "loss rows");
    for (ra, rb) in a.per_device_loss.iter().zip(&b.per_device_loss) {
        let bits = |r: &Vec<Option<f32>>| -> Vec<Option<u32>> {
            r.iter().map(|l| l.map(f32::to_bits)).collect()
        };
        // bit-compare so NaN losses count as equal too
        assert_eq!(bits(ra), bits(rb), "losses");
    }
    assert_eq!(a.ledger, b.ledger, "ledger");
    assert_eq!(a.movement.per_interval, b.movement.per_interval, "movement");
    assert_eq!(a.similarity, b.similarity, "similarity");
    assert_eq!(a.mean_active, b.mean_active, "mean_active");
    assert_eq!(a.total_collected, b.total_collected, "total_collected");
}

fn opts_blob() -> Json {
    Json::obj(vec![
        ("seeds", Json::from(1usize)),
        ("model", Json::Null),
        ("curve", Json::from(false)),
        ("eval_schedule", Json::from("full")),
    ])
}

fn mk_file(experiment: &str, index: usize, count: usize, total: usize, grid: u64) -> ShardFile {
    let spec = ShardSpec { index, count };
    ShardFile {
        experiment: experiment.into(),
        spec,
        total_runs: total,
        grid_fingerprint: grid,
        opts: opts_blob(),
        runs: (0..total)
            .filter(|j| spec.owns(*j))
            .map(|j| RunRecord {
                index: j,
                fingerprint: 0x42 + j as u64,
                output: EngineOutput::default(),
            })
            .collect(),
    }
}

#[test]
fn shard_file_serde_round_trip() {
    let f = ShardFile {
        experiment: "fig9".into(),
        spec: ShardSpec { index: 2, count: 3 },
        total_runs: 5,
        grid_fingerprint: u64::MAX,
        opts: opts_blob(),
        runs: vec![
            RunRecord { index: 1, fingerprint: 0xdead_beef, output: awkward_output() },
            RunRecord { index: 4, fingerprint: 7, output: EngineOutput::default() },
        ],
    };
    let dir = scratch("serde");
    let path = f.save(&dir).unwrap();
    assert_eq!(path.file_name().unwrap().to_str(), Some("shard_2_of_3.json"));

    let back = ShardFile::load(&path).unwrap();
    assert_eq!(back.experiment, "fig9");
    assert_eq!(back.spec, f.spec);
    assert_eq!(back.total_runs, 5);
    assert_eq!(back.grid_fingerprint, u64::MAX);
    assert_eq!(back.opts, f.opts);
    assert_eq!(back.runs.len(), 2);
    for (a, b) in f.runs.iter().zip(&back.runs) {
        assert_eq!(a.index, b.index);
        assert_eq!(a.fingerprint, b.fingerprint);
        assert_output_eq(&a.output, &b.output);
    }
}

#[test]
fn binary_shard_file_round_trips_awkward_floats() {
    let f = ShardFile {
        experiment: "fig9".into(),
        spec: ShardSpec { index: 2, count: 3 },
        total_runs: 5,
        grid_fingerprint: u64::MAX,
        opts: opts_blob(),
        runs: vec![
            RunRecord { index: 1, fingerprint: 0xdead_beef, output: awkward_output() },
            RunRecord { index: 4, fingerprint: 7, output: EngineOutput::default() },
        ],
    };
    let dir = scratch("binfmt_rt");
    let path = f.save_as(&dir, ShardFormat::Binary).unwrap();
    assert_eq!(path.file_name().unwrap().to_str(), Some("shard_2_of_3.fsb"));

    let back = ShardFile::load(&path).unwrap();
    assert_eq!(back.experiment, "fig9");
    assert_eq!(back.spec, f.spec);
    assert_eq!(back.total_runs, 5);
    assert_eq!(back.grid_fingerprint, u64::MAX);
    assert_eq!(back.opts, f.opts);
    assert_eq!(back.runs.len(), 2);
    for (a, b) in f.runs.iter().zip(&back.runs) {
        assert_eq!(a.index, b.index);
        assert_eq!(a.fingerprint, b.fingerprint);
        assert_output_eq(&a.output, &b.output);
    }
}

#[test]
fn binary_and_json_shard_sets_load_identically() {
    // the same grid persisted both ways must reassemble into the same
    // ShardSet — the pure-CPU half of the merge-equivalence contract
    let jdir = scratch("sets_json");
    let bdir = scratch("sets_bin");
    for i in 1..=2 {
        let f = mk_file("table3", i, 2, 4, 7);
        f.save(&jdir).unwrap();
        f.save_as(&bdir, ShardFormat::Binary).unwrap();
    }
    let js = shard::load_shard_set(&jdir).unwrap();
    let bs = shard::load_shard_set(&bdir).unwrap();
    assert_eq!(js.experiment, bs.experiment);
    assert_eq!(js.count, bs.count);
    assert_eq!(js.opts, bs.opts);
    assert_eq!(js.runs.len(), bs.runs.len());
    for (a, b) in js.runs.iter().zip(&bs.runs) {
        assert_eq!(a.index, b.index);
        assert_eq!(a.fingerprint, b.fingerprint);
        assert_output_eq(&a.output, &b.output);
    }
}

#[test]
fn load_shard_set_rejects_mixed_formats() {
    let dir = scratch("mixed_formats");
    mk_file("table3", 1, 2, 4, 7).save(&dir).unwrap();
    mk_file("table3", 2, 2, 4, 7).save_as(&dir, ShardFormat::Binary).unwrap();
    let err = shard::load_shard_set(&dir).unwrap_err().to_string();
    assert!(err.contains("mixed shard formats"), "unhelpful error: {err}");
    assert!(err.contains("shard convert"), "error should point at the fix: {err}");
}

#[test]
fn load_shard_set_rejects_mixed_participation_schedules() {
    // the participation schedule is a grid *identity* (DESIGN §Perf
    // rule 13): shards recorded under different schedules sample
    // different device subsets, so merging them would silently mix
    // incomparable runs. The recorded-options check must refuse the
    // set in both on-disk formats.
    for format in [ShardFormat::Json, ShardFormat::Binary] {
        let dir = scratch(&format!("mixed_participation_{}", format.extension()));
        let participation_blob = |label: &str| {
            Json::obj(vec![
                ("seeds", Json::from(1usize)),
                ("model", Json::Null),
                ("curve", Json::from(false)),
                ("eval_schedule", Json::from("full")),
                ("participation", Json::from(label)),
            ])
        };
        let mut f1 = mk_file("table3", 1, 2, 4, 7);
        f1.opts = participation_blob("full");
        f1.save_as(&dir, format).unwrap();
        let mut f2 = mk_file("table3", 2, 2, 4, 7);
        f2.opts = participation_blob("uniform:2");
        f2.save_as(&dir, format).unwrap();
        let err = shard::load_shard_set(&dir).unwrap_err().to_string();
        assert!(
            err.contains("recorded options disagree"),
            "unhelpful error (.{} shards): {err}",
            format.extension()
        );
    }
}

#[test]
fn load_shard_set_ignores_unrelated_files() {
    let dir = scratch("unrelated");
    mk_file("table3", 1, 2, 4, 7).save(&dir).unwrap();
    mk_file("table3", 2, 2, 4, 7).save(&dir).unwrap();
    // debris that must NOT be mistaken for shards (or trip the
    // mixed-format check): backups, editor temp files, partial
    // downloads, junk
    for name in [
        "shard_1_of_2.json.bak",
        "shard_2_of_2.json~",
        ".#shard_1_of_2.json",
        "#shard_1_of_2.json#",
        ".shard_1_of_2.json.swp",
        "shard_1_of_2.fsb.partial",
        "notes.txt",
    ] {
        fs::write(dir.join(name), b"junk").unwrap();
    }
    fs::create_dir_all(dir.join("shard_9_of_9.json")).unwrap(); // a *directory* with a shard name
    let set = shard::load_shard_set(&dir).unwrap();
    assert_eq!(set.count, 2);
    assert_eq!(set.runs.len(), 4);
}

#[test]
fn load_shard_set_accepts_complete_sets() {
    let dir = scratch("validate_ok");
    mk_file("table3", 1, 2, 4, 7).save(&dir).unwrap();
    mk_file("table3", 2, 2, 4, 7).save(&dir).unwrap();
    let set = shard::load_shard_set(&dir).unwrap();
    assert_eq!(set.experiment, "table3");
    assert_eq!(set.count, 2);
    assert_eq!(set.runs.len(), 4);
    // reassembled in canonical order regardless of per-file grouping
    for (j, r) in set.runs.iter().enumerate() {
        assert_eq!(r.index, j);
        assert_eq!(r.fingerprint, 0x42 + j as u64);
    }
}

#[test]
fn load_shard_set_rejects_missing_shard() {
    let dir = scratch("validate_missing");
    mk_file("table3", 1, 3, 6, 7).save(&dir).unwrap();
    mk_file("table3", 3, 3, 6, 7).save(&dir).unwrap();
    let err = shard::load_shard_set(&dir).unwrap_err().to_string();
    assert!(err.contains("missing shard"), "unhelpful error: {err}");
}

#[test]
fn load_shard_set_rejects_fingerprint_mismatch() {
    let dir = scratch("validate_fp");
    mk_file("table3", 1, 2, 4, 7).save(&dir).unwrap();
    mk_file("table3", 2, 2, 4, 8).save(&dir).unwrap();
    let err = shard::load_shard_set(&dir).unwrap_err().to_string();
    assert!(err.contains("grid fingerprint"), "unhelpful error: {err}");
}

#[test]
fn load_shard_set_rejects_truncated_shard() {
    let dir = scratch("validate_trunc");
    mk_file("table3", 1, 2, 4, 7).save(&dir).unwrap();
    let mut f2 = mk_file("table3", 2, 2, 4, 7);
    f2.runs.pop();
    f2.save(&dir).unwrap();
    let err = shard::load_shard_set(&dir).unwrap_err().to_string();
    assert!(err.contains("missing"), "unhelpful error: {err}");
}

#[test]
fn load_shard_set_rejects_mixed_counts_and_empty_dirs() {
    let dir = scratch("validate_mixed");
    mk_file("table3", 1, 2, 4, 7).save(&dir).unwrap();
    mk_file("table3", 2, 3, 4, 7).save(&dir).unwrap();
    let err = shard::load_shard_set(&dir).unwrap_err().to_string();
    assert!(err.contains("mixed"), "unhelpful error: {err}");

    let empty = scratch("validate_empty");
    let err = shard::load_shard_set(&empty).unwrap_err().to_string();
    assert!(err.contains("no shard files"), "unhelpful error: {err}");
}

#[test]
fn merge_rejects_experiment_it_cannot_replay() {
    let dir = scratch("validate_exp");
    mk_file("theory", 1, 1, 1, 7).save(&dir).unwrap();
    let err = experiments::merge(dir.to_str().unwrap(), None).unwrap_err().to_string();
    assert!(err.contains("not shardable"), "unhelpful error: {err}");
}
