//! Batched ≡ scalar equivalence: a full session run must produce the same
//! results whether local updates dispatch per device (`TrainPath::Scalar`)
//! or as stacked `[D × BATCH]` multi-device executions
//! (`TrainPath::Batched`). Requires `make artifacts`.
//!
//! What "the same" means (DESIGN.md §Perf rule 7): everything training
//! numerics cannot reach — the ledger, movement totals, mean-active — is
//! bit-identical, because the movement optimization never reads model
//! parameters. Losses and accuracies agree within a small tolerance: the
//! vmapped lowering computes the same per-device math, but XLA may order
//! the batched reductions differently after optimization.

use fogml::config::{Churn, EngineConfig, Method, TrainPath};
use fogml::fed::{self, EngineOutput};
use fogml::runtime::Runtime;

const LOSS_TOL: f32 = 1e-4;
const ACC_TOL: f64 = 5e-3;

fn small() -> EngineConfig {
    EngineConfig {
        method: Method::NetworkAware,
        n: 8,
        t_max: 20,
        tau: 5,
        n_train: 1600,
        n_test: 400,
        eval_curve: true,
        // churn makes some intervals single-trainee, exercising the
        // scalar fallback inside the batched configuration too
        churn: Some(Churn { p_exit: 0.05, p_entry: 0.05 }),
        ..Default::default()
    }
}

fn run_path(rt: &Runtime, path: TrainPath) -> EngineOutput {
    let cfg = small().with(|c| c.train_path = path);
    fed::run(&cfg, rt).expect("session run")
}

fn assert_equivalent(a: &EngineOutput, b: &EngineOutput, label: &str) {
    // bookkeeping untouched by training numerics: exact
    assert_eq!(a.ledger, b.ledger, "{label}: ledger");
    assert_eq!(a.movement.per_interval, b.movement.per_interval, "{label}: movement");
    assert_eq!(a.mean_active, b.mean_active, "{label}: mean_active");
    assert_eq!(a.total_collected, b.total_collected, "{label}: collected");
    assert_eq!(a.similarity, b.similarity, "{label}: similarity");

    // training numerics: tolerance
    assert!(
        (a.accuracy - b.accuracy).abs() <= ACC_TOL,
        "{label}: accuracy {} vs {}",
        a.accuracy,
        b.accuracy
    );
    assert_eq!(a.accuracy_curve.len(), b.accuracy_curve.len(), "{label}: curve len");
    for ((ta, aa), (tb, ab)) in a.accuracy_curve.iter().zip(&b.accuracy_curve) {
        assert_eq!(ta, tb, "{label}: curve t");
        assert!((aa - ab).abs() <= ACC_TOL, "{label}: curve t={ta}: {aa} vs {ab}");
    }
    assert_eq!(a.per_device_loss.len(), b.per_device_loss.len());
    for (t, (ra, rb)) in a.per_device_loss.iter().zip(&b.per_device_loss).enumerate() {
        for (i, (la, lb)) in ra.iter().zip(rb).enumerate() {
            match (la, lb) {
                (None, None) => {}
                (Some(la), Some(lb)) => assert!(
                    (la - lb).abs() <= LOSS_TOL * (1.0 + la.abs()),
                    "{label}: loss t={t} dev={i}: {la} vs {lb}"
                ),
                other => panic!("{label}: loss presence t={t} dev={i}: {other:?}"),
            }
        }
    }
}

#[test]
fn batched_and_scalar_sessions_are_equivalent() {
    let Some(rt) = fogml::runtime::test_runtime() else { return };
    let scalar = run_path(&rt, TrainPath::Scalar);
    let batched = run_path(&rt, TrainPath::Batched);
    let auto = run_path(&rt, TrainPath::Auto);
    assert_equivalent(&scalar, &batched, "scalar vs batched");
    assert_equivalent(&scalar, &auto, "scalar vs auto");

    // the run must have actually trained multiple devices at once for
    // this test to mean anything
    let multi_intervals = scalar
        .per_device_loss
        .iter()
        .filter(|row| row.iter().filter(|l| l.is_some()).count() > 1)
        .count();
    assert!(multi_intervals > 5, "only {multi_intervals} multi-trainee intervals");
}
