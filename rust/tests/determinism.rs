//! Determinism regression: the same `EngineConfig` must produce
//! bit-identical `EngineOutput` whether run serially (`fed::run`), through
//! `SimPool` with one job, or through `SimPool` with four jobs. This is
//! the contract that makes the pooled sweep drivers trustworthy: `--jobs`
//! changes wall-clock, never numbers. The coalescing-scheduler tests
//! extend it: through shared coalescing services (`--services K`),
//! outputs are additionally invariant to the partner runs that share the
//! stacked dispatches, to K, and to arrival order (DESIGN.md §Perf
//! rule 10), and so is the movement solvers' worker count
//! (`--solver-threads`; §Perf rule 12). Requires `make artifacts`; skips
//! without an XLA backend (the pure-CPU CI gate).

use fogml::config::{Churn, EngineConfig, Method, MovementBackend, TrainPath};
use fogml::coordinator::SimPool;
use fogml::experiments::common::{run_avg_pool, seed_sweep};
use fogml::fed::eval::{EvalPath, EvalSchedule};
use fogml::fed::{self, EngineOutput};

fn small() -> EngineConfig {
    EngineConfig {
        method: Method::NetworkAware,
        n: 5,
        t_max: 20,
        tau: 5,
        n_train: 1200,
        n_test: 300,
        // churn exercises the per-session RNG clone path too
        churn: Some(Churn { p_exit: 0.03, p_entry: 0.03 }),
        ..Default::default()
    }
}

fn assert_identical(a: &EngineOutput, b: &EngineOutput, label: &str) {
    assert_eq!(a.accuracy, b.accuracy, "{label}: accuracy");
    assert_eq!(a.accuracy_curve, b.accuracy_curve, "{label}: curve");
    assert_eq!(a.per_device_loss, b.per_device_loss, "{label}: losses");
    assert_eq!(a.ledger, b.ledger, "{label}: ledger");
    assert_eq!(
        a.movement.per_interval, b.movement.per_interval,
        "{label}: movement"
    );
    assert_eq!(a.similarity, b.similarity, "{label}: similarity");
    assert_eq!(a.mean_active, b.mean_active, "{label}: mean_active");
    assert_eq!(a.total_collected, b.total_collected, "{label}: collected");
}

#[test]
fn serial_pool1_and_pool4_are_bit_identical() {
    let cfgs = seed_sweep(&small(), 3);

    let Some(rt) = fogml::runtime::test_runtime() else { return };
    let serial: Vec<EngineOutput> = cfgs
        .iter()
        .map(|c| fed::run(c, &rt).expect("serial run"))
        .collect();

    // run_avg_pool expands the same seed grid internally (seed_sweep)
    let pool1 = SimPool::new(1);
    let (_, pooled1) = run_avg_pool(&pool1, &small(), 3).expect("pool --jobs 1");

    let pool4 = SimPool::new(4);
    let (_, pooled4) = run_avg_pool(&pool4, &small(), 3).expect("pool --jobs 4");

    // the shared-service shape: 4 workers interleaving requests on ONE
    // runtime-service thread (the riskiest configuration for cross-run
    // isolation of dataset ids and trainer caches)
    let shared = SimPool::with_services(4, 1);
    let pooled_shared = shared.run_many(&cfgs).expect("pool jobs=4, services=1");

    assert_eq!(serial.len(), pooled1.len());
    assert_eq!(serial.len(), pooled4.len());
    assert_eq!(serial.len(), pooled_shared.len());
    for (k, s) in serial.iter().enumerate() {
        assert_identical(s, &pooled1[k], &format!("seed #{k}, serial vs jobs=1"));
        assert_identical(s, &pooled4[k], &format!("seed #{k}, serial vs jobs=4"));
        assert_identical(
            s,
            &pooled_shared[k],
            &format!("seed #{k}, serial vs jobs=4/shared-service"),
        );
    }
}

/// The batched multi-device path must honor the same contract: with
/// `TrainPath::Batched` forced, serial `fed::run` (LocalCompute →
/// `Trainer::train_interval_many`) and pooled runs (RuntimeHandle →
/// service-thread `TrainMany`) are bit-identical — both stack the same
/// device work in the same order through the same executable. The default
/// `small()` config above already exercises the Auto route; this pins the
/// forced-batched one, including single-trainee intervals.
#[test]
fn batched_path_is_pool_invariant() {
    let cfg = small().with(|c| {
        c.n = 8;
        c.train_path = TrainPath::Batched;
    });
    let cfgs = seed_sweep(&cfg, 2);

    let Some(rt) = fogml::runtime::test_runtime() else { return };
    let serial: Vec<EngineOutput> = cfgs
        .iter()
        .map(|c| fed::run(c, &rt).expect("serial batched run"))
        .collect();

    let pool = SimPool::new(4);
    let pooled = pool.run_many(&cfgs).expect("pooled batched runs");
    let shared = SimPool::with_services(4, 1);
    let pooled_shared = shared.run_many(&cfgs).expect("shared-service batched runs");

    for (k, s) in serial.iter().enumerate() {
        assert_identical(s, &pooled[k], &format!("batched seed #{k}, serial vs jobs=4"));
        assert_identical(
            s,
            &pooled_shared[k],
            &format!("batched seed #{k}, serial vs shared-service"),
        );
    }
}

/// The subset eval schedule must honor the same contract: the seeded
/// shard rotation and the stacked eval dispatch depend only on the
/// config, so a curve-producing run is bit-identical whether the
/// evaluations happen on the calling thread (LocalCompute →
/// `Trainer::evaluate_many`) or through pooled `EvalMany` service
/// round-trips — `assert_identical` covers `accuracy_curve`.
#[test]
fn subset_eval_schedule_is_pool_invariant() {
    let cfg = small().with(|c| {
        c.eval_curve = true;
        c.eval_schedule = EvalSchedule::Subset { shards: 4 };
        // force the stacked execution so the riskiest path (batched
        // EvalMany on the service thread) is the one pinned here
        c.eval_path = EvalPath::Batched;
    });
    let cfgs = seed_sweep(&cfg, 2);

    let Some(rt) = fogml::runtime::test_runtime() else { return };
    let serial: Vec<EngineOutput> = cfgs
        .iter()
        .map(|c| fed::run(c, &rt).expect("serial subset-eval run"))
        .collect();
    for s in &serial {
        assert_eq!(s.accuracy_curve.len(), cfg.t_max / cfg.tau);
    }

    let pool1 = SimPool::new(1);
    let pooled1 = pool1.run_many(&cfgs).expect("subset eval jobs=1");
    let pool4 = SimPool::new(4);
    let pooled4 = pool4.run_many(&cfgs).expect("subset eval jobs=4");

    for (k, s) in serial.iter().enumerate() {
        assert_identical(s, &pooled1[k], &format!("subset seed #{k}, serial vs jobs=1"));
        assert_identical(s, &pooled4[k], &format!("subset seed #{k}, serial vs jobs=4"));
    }
}

/// The coalescing-scheduler contract (DESIGN.md §Perf rule 10): a run's
/// output through shared coalescing services is **bit-identical** no
/// matter
/// * how many jobs race their requests into the scheduler (`--jobs`),
/// * how many services split the pool (`--services K`),
/// * which partner runs share its stacked dispatches — same-(model, lr)
///   partners that pack into the *same* largest-tile executions, and
///   other-lr partners that form sibling groups,
/// * channel arrival order (the work-stealing pool randomizes it).
///
/// The riskiest surfaces are pinned: batched multi-device training and
/// batched subset-schedule curve evaluation, both of which coalesce.
#[test]
fn coalesced_dispatch_is_partner_invariant() {
    if !fogml::runtime::backend_available() {
        return;
    }
    let cfg = small().with(|c| {
        c.n = 8;
        c.train_path = TrainPath::Batched;
        c.eval_curve = true;
        c.eval_schedule = EvalSchedule::Subset { shards: 4 };
        c.eval_path = EvalPath::Batched;
    });
    let cfgs = seed_sweep(&cfg, 2);

    // reference: --jobs 1 through one coalescing service (every dispatch
    // carries only this run's slots, but through the same tile policy)
    let reference = SimPool::coalescing(1, 1).run_many(&cfgs).expect("jobs=1 coalesced");
    for r in &reference {
        assert_eq!(r.accuracy_curve.len(), cfg.t_max / cfg.tau);
    }

    // the same two runs co-scheduled against each other on one service
    let coalesced = SimPool::coalescing(4, 1).run_many(&cfgs).expect("jobs=4 coalesced");

    // split across two services (whichever service a run lands on, and
    // whoever it shares it with, must not matter)
    let two_services = SimPool::coalescing(4, 2).run_many(&cfgs).expect("services=2");

    // alien partner mix: a same-lr partner (packs into the same dispatch
    // groups) and a different-lr partner (forms a sibling group in the
    // same scheduling cycles)
    let mixed: Vec<EngineConfig> = vec![
        cfg.clone().with(|c| c.n = 3).seeded(777),
        cfgs[0].clone(),
        cfg.clone().with(|c| c.lr = 0.02).seeded(778),
        cfgs[1].clone(),
    ];
    let mixed_out = SimPool::coalescing(4, 1).run_many(&mixed).expect("partner mix");

    for (k, r) in reference.iter().enumerate() {
        assert_identical(r, &coalesced[k], &format!("coalesced seed #{k}, jobs=1 vs jobs=4"));
        assert_identical(
            r,
            &two_services[k],
            &format!("coalesced seed #{k}, services=1 vs services=2"),
        );
    }
    assert_identical(&reference[0], &mixed_out[1], "seed #0 vs alien-partner mix");
    assert_identical(&reference[1], &mixed_out[3], "seed #1 vs alien-partner mix");
}

/// The movement backend is a pure execution-strategy knob (DESIGN.md
/// §Perf rule 11): with everything else equal, `Dense`, `Sparse`, and the
/// default `Auto` runs are bit-identical end-to-end — the sparse engine
/// mirrors the dense solvers exactly, through training, churn, and the
/// plan-apportionment data movement. And with the default
/// `warm_start: false`, a repeated run reproduces itself bitwise.
#[test]
fn movement_backend_and_warm_start_defaults_are_bit_identical() {
    let Some(rt) = fogml::runtime::test_runtime() else { return };
    let base = small();
    let dense = fed::run(
        &base.clone().with(|c| c.movement_backend = MovementBackend::Dense),
        &rt,
    )
    .expect("dense-backend run");
    let sparse_cfg = base.clone().with(|c| c.movement_backend = MovementBackend::Sparse);
    let sparse = fed::run(&sparse_cfg, &rt).expect("sparse-backend run");
    let auto = fed::run(&base, &rt).expect("auto-backend run");

    assert_identical(&dense, &sparse, "dense vs sparse backend");
    assert_identical(&dense, &auto, "dense vs auto backend");

    // warm_start defaults off: a fresh run of the same config is an exact
    // replay (nothing solver-side carries over between runs)
    let again = fed::run(&sparse_cfg, &rt).expect("sparse-backend rerun");
    assert_identical(&sparse, &again, "sparse rerun, warm_start off");
}

/// The solver-threads knob is a pure execution-strategy knob too
/// (DESIGN.md §Perf rule 12): the row-parallel movement passes use
/// fixed-size chunks whose geometry depends only on n, with reductions
/// combined in ascending chunk order, so the default (`Auto`), `Fixed(1)`
/// and oversubscribed `Fixed` runs are bit-identical end-to-end — through
/// training, churn, repair, and plan apportionment on both backends.
#[test]
fn solver_threads_default_is_bit_identical() {
    use fogml::config::SolverThreads;
    let Some(rt) = fogml::runtime::test_runtime() else { return };
    for backend in [MovementBackend::Dense, MovementBackend::Sparse] {
        let base = small().with(|c| c.movement_backend = backend);
        let reference = fed::run(&base, &rt).expect("default (Auto) run");
        for threads in [1usize, 2, 4] {
            let forced = fed::run(
                &base.clone().with(|c| c.solver_threads = SolverThreads::Fixed(threads)),
                &rt,
            )
            .expect("forced-threads run");
            assert_identical(
                &reference,
                &forced,
                &format!("{backend:?} backend, Auto vs Fixed({threads})"),
            );
        }
    }
}

/// The centralized baseline must round-trip through the pool identically
/// too (it takes the no-network code path inside the session layer).
#[test]
fn centralized_is_pool_invariant() {
    let cfg = small().with(|c| {
        c.method = Method::Centralized;
        c.churn = None;
    });
    let Some(rt) = fogml::runtime::test_runtime() else { return };
    let serial = fed::run(&cfg, &rt).expect("serial centralized");
    let pool = SimPool::new(2);
    let pooled = pool.run_many(std::slice::from_ref(&cfg)).expect("pooled centralized");
    assert_identical(&serial, &pooled[0], "centralized");
}
