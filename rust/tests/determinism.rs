//! Determinism regression: the same `EngineConfig` must produce
//! bit-identical `EngineOutput` whether run serially (`fed::run`), through
//! `SimPool` with one job, or through `SimPool` with four jobs. This is
//! the contract that makes the pooled sweep drivers trustworthy: `--jobs`
//! changes wall-clock, never numbers. Requires `make artifacts`.

use fogml::config::{Churn, EngineConfig, Method, TrainPath};
use fogml::coordinator::SimPool;
use fogml::experiments::common::{run_avg_pool, seed_sweep};
use fogml::fed::eval::{EvalPath, EvalSchedule};
use fogml::fed::{self, EngineOutput};
use fogml::runtime::Runtime;

fn small() -> EngineConfig {
    EngineConfig {
        method: Method::NetworkAware,
        n: 5,
        t_max: 20,
        tau: 5,
        n_train: 1200,
        n_test: 300,
        // churn exercises the per-session RNG clone path too
        churn: Some(Churn { p_exit: 0.03, p_entry: 0.03 }),
        ..Default::default()
    }
}

fn assert_identical(a: &EngineOutput, b: &EngineOutput, label: &str) {
    assert_eq!(a.accuracy, b.accuracy, "{label}: accuracy");
    assert_eq!(a.accuracy_curve, b.accuracy_curve, "{label}: curve");
    assert_eq!(a.per_device_loss, b.per_device_loss, "{label}: losses");
    assert_eq!(a.ledger, b.ledger, "{label}: ledger");
    assert_eq!(
        a.movement.per_interval, b.movement.per_interval,
        "{label}: movement"
    );
    assert_eq!(a.similarity, b.similarity, "{label}: similarity");
    assert_eq!(a.mean_active, b.mean_active, "{label}: mean_active");
    assert_eq!(a.total_collected, b.total_collected, "{label}: collected");
}

#[test]
fn serial_pool1_and_pool4_are_bit_identical() {
    let cfgs = seed_sweep(&small(), 3);

    let rt = Runtime::load_default().expect("run `make artifacts` first");
    let serial: Vec<EngineOutput> = cfgs
        .iter()
        .map(|c| fed::run(c, &rt).expect("serial run"))
        .collect();

    // run_avg_pool expands the same seed grid internally (seed_sweep)
    let pool1 = SimPool::new(1);
    let (_, pooled1) = run_avg_pool(&pool1, &small(), 3).expect("pool --jobs 1");

    let pool4 = SimPool::new(4);
    let (_, pooled4) = run_avg_pool(&pool4, &small(), 3).expect("pool --jobs 4");

    // the shared-service shape: 4 workers interleaving requests on ONE
    // runtime-service thread (the riskiest configuration for cross-run
    // isolation of dataset ids and trainer caches)
    let shared = SimPool::with_services(4, 1);
    let pooled_shared = shared.run_many(&cfgs).expect("pool jobs=4, services=1");

    assert_eq!(serial.len(), pooled1.len());
    assert_eq!(serial.len(), pooled4.len());
    assert_eq!(serial.len(), pooled_shared.len());
    for (k, s) in serial.iter().enumerate() {
        assert_identical(s, &pooled1[k], &format!("seed #{k}, serial vs jobs=1"));
        assert_identical(s, &pooled4[k], &format!("seed #{k}, serial vs jobs=4"));
        assert_identical(
            s,
            &pooled_shared[k],
            &format!("seed #{k}, serial vs jobs=4/shared-service"),
        );
    }
}

/// The batched multi-device path must honor the same contract: with
/// `TrainPath::Batched` forced, serial `fed::run` (LocalCompute →
/// `Trainer::train_interval_many`) and pooled runs (RuntimeHandle →
/// service-thread `TrainMany`) are bit-identical — both stack the same
/// device work in the same order through the same executable. The default
/// `small()` config above already exercises the Auto route; this pins the
/// forced-batched one, including single-trainee intervals.
#[test]
fn batched_path_is_pool_invariant() {
    let cfg = small().with(|c| {
        c.n = 8;
        c.train_path = TrainPath::Batched;
    });
    let cfgs = seed_sweep(&cfg, 2);

    let rt = Runtime::load_default().expect("run `make artifacts` first");
    let serial: Vec<EngineOutput> = cfgs
        .iter()
        .map(|c| fed::run(c, &rt).expect("serial batched run"))
        .collect();

    let pool = SimPool::new(4);
    let pooled = pool.run_many(&cfgs).expect("pooled batched runs");
    let shared = SimPool::with_services(4, 1);
    let pooled_shared = shared.run_many(&cfgs).expect("shared-service batched runs");

    for (k, s) in serial.iter().enumerate() {
        assert_identical(s, &pooled[k], &format!("batched seed #{k}, serial vs jobs=4"));
        assert_identical(
            s,
            &pooled_shared[k],
            &format!("batched seed #{k}, serial vs shared-service"),
        );
    }
}

/// The subset eval schedule must honor the same contract: the seeded
/// shard rotation and the stacked eval dispatch depend only on the
/// config, so a curve-producing run is bit-identical whether the
/// evaluations happen on the calling thread (LocalCompute →
/// `Trainer::evaluate_many`) or through pooled `EvalMany` service
/// round-trips — `assert_identical` covers `accuracy_curve`.
#[test]
fn subset_eval_schedule_is_pool_invariant() {
    let cfg = small().with(|c| {
        c.eval_curve = true;
        c.eval_schedule = EvalSchedule::Subset { shards: 4 };
        // force the stacked execution so the riskiest path (batched
        // EvalMany on the service thread) is the one pinned here
        c.eval_path = EvalPath::Batched;
    });
    let cfgs = seed_sweep(&cfg, 2);

    let rt = Runtime::load_default().expect("run `make artifacts` first");
    let serial: Vec<EngineOutput> = cfgs
        .iter()
        .map(|c| fed::run(c, &rt).expect("serial subset-eval run"))
        .collect();
    for s in &serial {
        assert_eq!(s.accuracy_curve.len(), cfg.t_max / cfg.tau);
    }

    let pool1 = SimPool::new(1);
    let pooled1 = pool1.run_many(&cfgs).expect("subset eval jobs=1");
    let pool4 = SimPool::new(4);
    let pooled4 = pool4.run_many(&cfgs).expect("subset eval jobs=4");

    for (k, s) in serial.iter().enumerate() {
        assert_identical(s, &pooled1[k], &format!("subset seed #{k}, serial vs jobs=1"));
        assert_identical(s, &pooled4[k], &format!("subset seed #{k}, serial vs jobs=4"));
    }
}

/// The centralized baseline must round-trip through the pool identically
/// too (it takes the no-network code path inside the session layer).
#[test]
fn centralized_is_pool_invariant() {
    let cfg = small().with(|c| {
        c.method = Method::Centralized;
        c.churn = None;
    });
    let rt = Runtime::load_default().expect("run `make artifacts` first");
    let serial = fed::run(&cfg, &rt).expect("serial centralized");
    let pool = SimPool::new(2);
    let pooled = pool.run_many(std::slice::from_ref(&cfg)).expect("pooled centralized");
    assert_identical(&serial, &pooled[0], "centralized");
}
