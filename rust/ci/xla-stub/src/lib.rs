//! Pure-CPU stub of the `xla` crate API surface fogml uses (see
//! Cargo.toml for why it exists). Two rules:
//!
//! 1. `Literal` is a *working* host-side tensor container — creating,
//!    reading back and shape-querying literals needs no XLA, so the
//!    tensor-layer tests keep running under the CI hard gate.
//! 2. Everything that would touch PJRT or parse HLO returns an [`Error`]
//!    whose message contains the `"xla stub"` marker;
//!    `fogml::runtime::backend_available()` keys on it to skip
//!    runtime-dependent tests cleanly.

/// Stub error: every message carries the `xla stub` marker.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn stub(what: &str) -> Error {
        Error(format!(
            "xla stub: {what} is unavailable in this pure-CPU build (rust/ci/xla-stub)"
        ))
    }

    fn msg(m: String) -> Error {
        Error(format!("xla stub: {m}"))
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes (only what fogml stages: f32).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

/// Native types a [`Literal`] can stage/read (mirrors xla-rs's trait of
/// the same role; fogml only ever uses f32).
pub trait ArrayElement: Copy {
    const TY: ElementType;
}

impl ArrayElement for f32 {
    const TY: ElementType = ElementType::F32;
}

/// Array shape of a literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host-side tensor literal: fully functional in the stub (no XLA
/// involvement in creating or reading one).
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        untyped_data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = dims.iter().product();
        let elem_size = match ty {
            ElementType::F32 => std::mem::size_of::<f32>(),
        };
        if elems * elem_size != untyped_data.len() {
            return Err(Error::msg(format!(
                "literal shape {dims:?} needs {} bytes, got {}",
                elems * elem_size,
                untyped_data.len()
            )));
        }
        Ok(Literal {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            data: untyped_data.to_vec(),
        })
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error::msg(format!(
                "element type mismatch: literal is {:?}",
                self.ty
            )));
        }
        let n = self.data.len() / std::mem::size_of::<T>();
        let mut out: Vec<T> = Vec::with_capacity(n);
        // byte-wise copy into the (aligned) destination: the source Vec<u8>
        // carries no alignment guarantee for T
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.data.as_ptr(),
                out.as_mut_ptr() as *mut u8,
                n * std::mem::size_of::<T>(),
            );
            out.set_len(n);
        }
        Ok(out)
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    /// Tuple literals only come out of executions, which the stub never
    /// performs.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::stub("tuple decomposition"))
    }
}

/// Parsed HLO module (never constructible in the stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub("HLO text parsing"))
    }
}

/// An XLA computation wrapping a parsed module.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle (never constructible in the stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("device-to-host transfer"))
    }
}

/// Compiled executable handle (never constructible in the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("execution"))
    }
}

/// PJRT client (never constructible in the stub — this is the error
/// `backend_available()` probes for).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub("the PJRT CPU client"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("compilation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_container_works_without_xla() {
        let data: Vec<f32> = vec![1.0, 2.5, -3.0, 0.0, 9.75, 42.0];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_ne_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 3], &bytes)
                .unwrap();
        assert_eq!(lit.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        // wrong byte count is a loud error
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[4, 3],
            &bytes
        )
        .is_err());
    }

    #[test]
    fn pjrt_surface_errors_with_marker() {
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("xla stub"), "{err}");
        let err = HloModuleProto::from_text_file("/nope").unwrap_err().to_string();
        assert!(err.contains("xla stub"), "{err}");
    }
}
