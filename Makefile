# fogml build orchestration.
#
# `make artifacts` runs the L2 AOT pipeline (python/compile/aot.py): every
# entry point — scalar train/eval steps plus the batched
# `*_train_many_d<D>` device-stack variants — is lowered to HLO text under
# rust/artifacts/, which is also where the rust runtime looks by default
# when invoked from rust/ (override with FOGML_ARTIFACTS). The generated
# artifacts are vendored in-repo so `cargo test` works without a JAX
# toolchain; re-run this target after changing python/compile/.

PYTHON ?= python3
ARTIFACTS_DIR := $(abspath rust/artifacts)

.PHONY: artifacts test-python test-rust

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir $(ARTIFACTS_DIR)

test-python:
	cd python && $(PYTHON) -m pytest -q tests

test-rust:
	cd rust && cargo test -q
