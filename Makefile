# fogml build orchestration.
#
# `make artifacts` runs the L2 AOT pipeline (python/compile/aot.py): every
# entry point — scalar train/eval steps plus the batched
# `*_train_many_d<D>` and `*_eval_many_d<D>` device-stack variants — is
# lowered to HLO text under rust/artifacts/, which is also where the rust
# runtime looks by default when invoked from rust/ (override with
# FOGML_ARTIFACTS). The generated artifacts are vendored in-repo so
# `cargo test` works without a JAX toolchain; re-run this target after
# changing python/compile/, and run `make check-artifacts` to verify the
# vendored set is not stale relative to python/compile.

PYTHON ?= python3
ARTIFACTS_DIR := $(abspath rust/artifacts)
CHECK_DIR := $(abspath rust/target/artifacts-check)

# every entry the rust runtime may request; `artifacts` fails loudly if
# the pipeline stops emitting one of them
REQUIRED_ENTRIES := mlp_train mlp_eval cnn_train cnn_eval dense_micro \
	$(foreach d,4 8 16 32,mlp_train_many_d$(d) cnn_train_many_d$(d) \
	mlp_eval_many_d$(d) cnn_eval_many_d$(d))

.PHONY: artifacts check-artifacts test-python test-rust bench

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir $(ARTIFACTS_DIR)
	@for e in $(REQUIRED_ENTRIES); do \
		grep -q "\"$$e\": {" $(ARTIFACTS_DIR)/manifest.json || \
		{ echo "FATAL: entry '$$e' missing from $(ARTIFACTS_DIR)/manifest.json"; exit 1; }; \
	done
	@echo "artifacts: all $(words $(REQUIRED_ENTRIES)) required entries present"

# regenerate into a scratch dir and compare the ABI manifest against the
# vendored one: a mismatch means rust/artifacts/ is stale relative to
# python/compile — re-run `make artifacts` and commit the result
check-artifacts:
	rm -rf $(CHECK_DIR) && mkdir -p $(CHECK_DIR)
	cd python && $(PYTHON) -m compile.aot --out-dir $(CHECK_DIR)
	@diff -u $(ARTIFACTS_DIR)/manifest.json $(CHECK_DIR)/manifest.json || \
	{ echo "FATAL: vendored rust/artifacts/manifest.json is STALE relative to python/compile —"; \
	  echo "       run 'make artifacts' and commit the regenerated artifacts."; exit 1; }
	@echo "check-artifacts: vendored manifest matches python/compile"

test-python:
	cd python && $(PYTHON) -m pytest -q tests

test-rust:
	cd rust && cargo test -q

# Engine perf trajectory (DESIGN.md §Perf rule 6): emits BENCH_engine.json
# in rust/ (plus a copy under rust/results/bench/) — serial vs pooled,
# batched vs scalar train/eval dispatch, and the coalesced vs per-session
# `service` section. Later perf PRs should beat and re-emit it.
bench:
	cd rust && cargo bench --bench bench_engine
